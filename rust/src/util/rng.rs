//! SplitMix64 PRNG — bit-identical to `python/compile/datagen.SplitMix64`.
//!
//! Used by the synthetic corpus generator (data::synthetic must produce
//! exactly the sentences Python exported) and by benches/tests that need
//! cheap deterministic randomness.

/// SplitMix64 (Steele et al., "Fast splittable pseudorandom number
/// generators", OOPSLA'14).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4B9FD);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[0, n)`; modulo bias is negligible for n << 2^64.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa, same as Python).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller (benches/tests only; not in Python).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with uniform floats in `[-scale, scale]`.
    pub fn fill_uniform_f32(&mut self, out: &mut [f32], scale: f32) {
        for x in out {
            *x = ((self.f64() * 2.0 - 1.0) as f32) * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    /// Golden values cross-checked against the Python implementation
    /// (`python/compile/datagen.SplitMix64`), which is the parity
    /// contract for corpus regeneration.
    #[test]
    fn matches_python_reference() {
        let mut zero = SplitMix64::new(0);
        assert_eq!(zero.next_u64(), 0x91a20293e6b0ff96);
        let mut one = SplitMix64::new(1);
        assert_eq!(one.next_u64(), 0x77deae211feb5fd2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = SplitMix64::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range(2, 5);
            assert!((2..=5).contains(&v));
            seen_lo |= v == 2;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }
}
