//! Minimal property-testing support (proptest is unavailable offline).
//!
//! `check` runs a property over `n` random cases from a seeded
//! [`SplitMix64`]; on failure it retries with progressively simpler
//! inputs is not attempted (no shrinking) but the failing seed and case
//! index are reported so the case is exactly reproducible.

use super::rng::SplitMix64;

/// Number of cases per property (overridable via `QUANTNMT_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("QUANTNMT_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` for `n` random cases; panics with seed + case on failure.
///
/// `prop` receives a per-case RNG and the case index and returns
/// `Result<(), String>`; `Err` fails the property with the message.
pub fn check<F>(name: &str, seed: u64, n: usize, mut prop: F)
where
    F: FnMut(&mut SplitMix64, usize) -> Result<(), String>,
{
    for case in 0..n {
        // each case gets an independent, reconstructible stream
        let mut rng = SplitMix64::new(seed ^ (case as u64).wrapping_mul(0x9E3779B9));
        if let Err(msg) = prop(&mut rng, case) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): {msg}\n\
                 reproduce with SplitMix64::new({seed} ^ ({case}u64 * 0x9E3779B9))"
            );
        }
    }
}

/// Generators for common shapes.
pub mod gen {
    use super::SplitMix64;

    /// Vec of f32 in [-scale, scale] of length in [min_len, max_len].
    pub fn f32_vec(rng: &mut SplitMix64, min_len: usize, max_len: usize, scale: f32) -> Vec<f32> {
        let n = rng.range(min_len as u64, max_len as u64) as usize;
        (0..n)
            .map(|_| ((rng.f64() * 2.0 - 1.0) as f32) * scale)
            .collect()
    }

    /// Vec with occasional large-magnitude outliers (long-tailed, like
    /// the paper's Fig 2 activations).
    pub fn f32_vec_longtail(rng: &mut SplitMix64, len: usize, scale: f32) -> Vec<f32> {
        (0..len)
            .map(|_| {
                let base = (rng.normal() as f32) * scale;
                if rng.f64() < 0.01 {
                    base * 20.0
                } else {
                    base
                }
            })
            .collect()
    }

    /// Random (m, k, n) GEMM dims within bounds.
    pub fn gemm_dims(rng: &mut SplitMix64, max: usize) -> (usize, usize, usize) {
        (
            rng.range(1, max as u64) as usize,
            rng.range(1, max as u64) as usize,
            rng.range(1, max as u64) as usize,
        )
    }

    /// Random token-id sequence (content ids only).
    pub fn token_seq(rng: &mut SplitMix64, max_len: usize, vocab: u32) -> Vec<u32> {
        let n = rng.range(1, max_len as u64) as usize;
        (0..n)
            .map(|_| crate::specials::FIRST_CONTENT_ID + rng.below((vocab - 3) as u64) as u32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 1, 32, |rng, _| {
            let a = rng.f64();
            let b = rng.f64();
            if a + b == b + a {
                Ok(())
            } else {
                Err("non-commutative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        check("always-fails", 2, 8, |_, _| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = SplitMix64::new(9);
        for _ in 0..50 {
            let v = gen::f32_vec(&mut rng, 1, 10, 2.0);
            assert!((1..=10).contains(&v.len()));
            assert!(v.iter().all(|x| x.abs() <= 2.0));
            let (m, k, n) = gen::gemm_dims(&mut rng, 32);
            assert!(m >= 1 && k >= 1 && n >= 1 && m <= 32 && k <= 32 && n <= 32);
        }
    }
}
