//! Dependency-light utilities.
//!
//! This environment is offline: only the `xla` crate's dependency
//! closure is available, so JSON, CLI parsing, the bench harness and
//! property-testing support are implemented here instead of pulling
//! serde/clap/criterion/proptest.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

/// Monotonic seconds since an arbitrary epoch (wraps `std::time::Instant`).
pub fn now() -> std::time::Instant {
    std::time::Instant::now()
}

/// FNV-1a 64-bit hash — the one stable, dependency-free hash shared by
/// recipe content identity and synthetic-calibration seeding.
pub fn fnv1a<I: IntoIterator<Item = u8>>(bytes: I) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}
