//! Dependency-light utilities.
//!
//! This environment is offline: only the `xla` crate's dependency
//! closure is available, so JSON, CLI parsing, the bench harness and
//! property-testing support are implemented here instead of pulling
//! serde/clap/criterion/proptest.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

/// Monotonic seconds since an arbitrary epoch (wraps `std::time::Instant`).
pub fn now() -> std::time::Instant {
    std::time::Instant::now()
}
