//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut out = Args::default();
        let mut iter = iter.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Value of `--name` constrained to `allowed`; a missing flag yields
    /// `default`, but an unknown value is a hard error listing the valid
    /// choices (used for enum-like flags such as
    /// `--policy fixed|token-budget|bin-pack`).
    pub fn get_choice<'a>(
        &'a self,
        name: &str,
        allowed: &[&'a str],
        default: &'a str,
    ) -> anyhow::Result<&'a str> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => match allowed.iter().copied().find(|&a| a == v) {
                Some(a) => Ok(a),
                None => {
                    anyhow::bail!("unknown --{name} '{v}' (valid: {})", allowed.join("|"))
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn flags_and_options() {
        let a = parse("serve --batch 64 --int8 --mode=symmetric input.txt");
        assert_eq!(a.positional, vec!["serve", "input.txt"]);
        assert_eq!(a.get("batch"), Some("64"));
        assert_eq!(a.get("mode"), Some("symmetric"));
        assert!(a.flag("int8"));
        assert!(!a.flag("fp32"));
    }

    #[test]
    fn numeric_defaults() {
        let a = parse("--batch 32");
        assert_eq!(a.get_usize("batch", 1), 32);
        assert_eq!(a.get_usize("streams", 4), 4);
        assert_eq!(a.get_f64("frac", 0.5), 0.5);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--verbose");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn option_followed_by_flag() {
        let a = parse("--out dir --quiet");
        assert_eq!(a.get("out"), Some("dir"));
        assert!(a.flag("quiet"));
    }

    #[test]
    fn choice_flags() {
        let allowed = ["fixed", "token-budget", "bin-pack"];
        let a = parse("--policy bin-pack --token-budget 1024");
        assert_eq!(a.get_choice("policy", &allowed, "fixed").unwrap(), "bin-pack");
        assert_eq!(a.get_usize("token-budget", 512), 1024);
        // a missing flag yields the default
        let c = parse("");
        assert_eq!(c.get_choice("policy", &allowed, "fixed").unwrap(), "fixed");
    }

    #[test]
    fn unknown_choice_is_a_hard_error() {
        let allowed = ["fixed", "token-budget", "bin-pack"];
        let b = parse("--policy zig-zag");
        let err = b.get_choice("policy", &allowed, "fixed");
        let msg = err.expect_err("must reject").to_string();
        assert!(msg.contains("unknown --policy 'zig-zag'"));
        assert!(msg.contains("fixed|token-budget|bin-pack"));
    }
}
