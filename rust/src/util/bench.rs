//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + repeated timed runs, reporting min/median/mean/p95 and a
//! derived throughput.  Used by every `rust/benches/*.rs` target
//! (compiled with `harness = false`).

use std::time::{Duration, Instant};

/// One benchmark measurement summary (times in seconds).
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub p95: f64,
}

impl Stats {
    /// items processed per second at the median time.
    pub fn per_sec(&self, items: f64) -> f64 {
        items / self.median
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    pub max_time: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: 3,
            iters: 15,
            max_time: Duration::from_secs(20),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: 1,
            iters: 5,
            max_time: Duration::from_secs(8),
        }
    }

    /// Time `f` repeatedly; returns summary stats.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.iters);
        let start = Instant::now();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
            if start.elapsed() > self.max_time && times.len() >= 3 {
                break;
            }
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        Stats {
            name: name.to_string(),
            iters: n,
            min: times[0],
            median: times[n / 2],
            mean: times.iter().sum::<f64>() / n as f64,
            p95: times[((n as f64 * 0.95) as usize).min(n - 1)],
        }
    }
}

/// Pretty time formatting.
pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Print a standard result row: name, median, throughput-ish extra.
pub fn report(stats: &Stats, extra: &str) {
    println!(
        "{:44} median {:>12}  min {:>12}  {}",
        stats.name,
        fmt_time(stats.median),
        fmt_time(stats.min),
        extra
    );
}

/// A black-box sink preventing the optimizer from deleting benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_reports_sane_stats() {
        let b = Bench {
            warmup: 1,
            iters: 5,
            max_time: Duration::from_secs(5),
        };
        let mut acc = 0u64;
        let s = b.run("spin", || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(s.iters >= 3);
        assert!(s.min <= s.median && s.median <= s.p95);
        assert!(s.min > 0.0);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
