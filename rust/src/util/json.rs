//! Minimal JSON parser/serializer (RFC 8259 subset).
//!
//! Handles everything the artifact interchange needs: objects, arrays,
//! strings with escapes, numbers (f64), booleans, null.  Parses the
//! multi-megabyte `dataset.json` in well under a second; not a general
//! streaming parser.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field access: `j.get("key")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
    /// Array of numbers -> `Vec<u32>` (token id lists).
    pub fn as_u32_vec(&self) -> Option<Vec<u32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|n| n as u32).collect())
    }
    /// Array of numbers -> `Vec<f64>`.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
    }

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let bytes = input.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json, JsonError> {
        let text = std::fs::read_to_string(path).map_err(|e| JsonError {
            msg: format!("read {}: {e}", path.display()),
            offset: 0,
        })?;
        Json::parse(&text)
    }
}

/// Parse / IO error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.into(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (surrogate pairs unsupported; artifacts are ASCII)
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes at once
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// --------------------------------------------------------------------------
// serialization
// --------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Convenience constructors for building JSON output.
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object from key/value pairs: `obj(&[("a", 1.0.into())])`.
pub fn obj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\"b\\cA""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\"b\\cA");
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":"d\ne"},"f":true,"g":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn u32_vec() {
        let j = Json::parse("[3, 4, 5]").unwrap();
        assert_eq!(j.as_u32_vec().unwrap(), vec![3, 4, 5]);
    }

    #[test]
    fn display_integers_clean() {
        assert_eq!(Json::Num(64.0).to_string(), "64");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
