//! quantnmt CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!
//! ```text
//! quantnmt info                         artifact + platform summary
//! quantnmt translate  [--limit N]       translate test sentences, show text
//! quantnmt run        [--streams N]     offline corpus throughput run (one Fig-8 bar)
//! quantnmt serve      [--shards N]      online server: replay a Poisson trace through
//!                                       the dynamic batcher, report latency percentiles
//! quantnmt ladder                       the full Fig-8 configuration ladder
//! quantnmt calibrate                    print the calibration table (§4.2)
//! quantnmt recipe derive|show|diff      per-site quantization recipes: derive one
//!                                       from calibration (artifacts or --synthetic),
//!                                       pretty-print + census-validate a recipe.json,
//!                                       or diff two recipes site by site
//! quantnmt graph-stats [--per-site]     §5.5 op-census of naive vs optimized passes;
//!                                       --per-site prints the interned MatMul site
//!                                       table (SiteId -> weight) cross-checked
//!                                       against the graph IR census
//! ```
//!
//! Common flags: `--artifacts DIR`, `--backend engine-fp32|engine-int8|pjrt-fp32|pjrt-int8`,
//! `--mode naive|symmetric|independent|conjugate`, `--recipe FILE`
//! (run/serve: execute an explicit `recipe.json` instead of the
//! mode-derived default — `--backend engine-int8 --mode M` stays as
//! sugar that derives the default recipe for M), `--batch N`,
//! `--streams N`, `--sort unsorted|words|tokens`,
//! `--policy fixed|token-budget|bin-pack`, `--token-budget N`
//! (padded-token budget per batch for the budget policies and the
//! online batcher), `--serial`, `--no-pin`, `--limit N`,
//! `--gemm-threads N` (worker threads per GEMM; 0 = auto, flops-gated
//! so calls too small to pay dispatch stay single-threaded; see also
//! `QUANTNMT_GEMM_THREADS` / `QUANTNMT_ISA`), `--gemm-pool off|auto|N`
//! (persistent GEMM worker pool: `auto` sizes to the thread budget,
//! `N` caps the lane count, `off` falls back to per-call scoped
//! spawns; see also `QUANTNMT_GEMM_POOL`).
//!
//! `serve` flags: `--shards N` (worker streams), `--max-wait-ms MS`
//! (batching deadline), `--token-budget N`, `--batch N` (row cap),
//! `--rate R` (offered load, req/s), `--queue-cap N` (admission bound),
//! `--seed S` (arrival trace seed), `--limit N` (requests to replay),
//! `--max-len N` (decode-length cap, default 56),
//! `--scheduler batch|continuous` (decode discipline: run-to-completion
//! dynamic batches vs iteration-level scheduling over a persistent
//! KV-cache slot pool with mid-flight admission; engine backends only
//! for `continuous`), `--slots N` (KV-cache slots per shard pool,
//! default = the `--batch` row cap), `--kv-budget-mb N` (continuous
//! only: cap each shard's paged KV pool by memory instead of worst
//! case per slot — admission then gates on free pages, and a slot that
//! outruns the budget mid-decode is force-finished with its response
//! flagged truncated, never a panic).
//!
//! Network front end: `serve --listen ADDR` binds the hand-rolled
//! HTTP/1.1 + SSE server instead of replaying a trace (implies
//! `--scheduler continuous`); `--serve-secs N` accepts connections for
//! N seconds (default 30) then drains gracefully; `--tenants FILE`
//! loads a tenant spec (JSON array of `{"name", "weight",
//! "rate_tokens_per_sec", "burst_tokens"}`) enabling weighted-fair
//! admission and per-tenant token rate limits — without it every
//! request lands on a single default tenant.
//!
//! `recipe derive` flags: `--synthetic` (deterministic synthetic
//! calibration table, no artifacts needed), `--mode M` (default mode),
//! `--quantize-sparse`, `--int8 "SEL=MODE,SEL"` (re-derive matched
//! sites under another mode), `--fp32 "SEL,SEL"` (glob selectors
//! forced to FP32; applied after `--int8`, so an FP32 exception always
//! wins over a broad re-mode), `--name NAME`, `--out FILE`
//! (default: stdout).
//!
//! Fully-integer decision kinds (`recipe derive`): `--fused "SEL,SEL"`
//! (INT8 sites requantize their i32 accumulator straight onto the
//! consumer's grid — no f32 round-trip), `--per-channel "SEL,SEL"`
//! (per-output-channel weight scales, resolved at plan build),
//! `--integer-ln "SEL,SEL"` / `--integer-softmax "SEL,SEL"` (flip the
//! matching LayerNorm / softmax op sites to the i32-domain and
//! fixed-point kernels; op sites are named `enc.0.ln1`,
//! `dec.0.self.softmax`, ...), and `--fully-integer` (sugar for all
//! four with `*` — when every MatMul site is also INT8, the engine
//! compiles the fully-integer plan: one f32↔int hop per phase).

use quantnmt::coordinator::server::{poisson_offsets, replay_trace, TranslateRequest};
use quantnmt::coordinator::service::DEFAULT_TOKEN_BUDGET;
use quantnmt::coordinator::{Backend, Scheduler, ServerConfig, Service, ServiceConfig, TenantSet};
use quantnmt::data::sorting::SortOrder;
use quantnmt::model::plan::SiteSet;
use quantnmt::model::ModelConfig;
use quantnmt::pipeline::policy::PolicyKind;
use quantnmt::quant::calibrate::CalibrationMode;
use quantnmt::quant::recipe::{Recipe, RecipeBuilder};
use quantnmt::quant::SiteTable;
use quantnmt::runtime::RtPrecision;
use quantnmt::util::cli::Args;
use std::path::Path;
use std::time::Duration;

fn parse_mode(args: &Args) -> anyhow::Result<CalibrationMode> {
    let m = args.get_or("mode", "symmetric");
    CalibrationMode::from_str(m).ok_or_else(|| {
        anyhow::anyhow!("unknown --mode '{m}' (valid: naive|symmetric|independent|conjugate)")
    })
}

/// Resolve the backend: an explicit `--recipe recipe.json` wins,
/// `--backend engine-int8 --mode M` is sugar deriving the default
/// recipe for M from the service's calibration table.
fn parse_backend(args: &Args, svc: &Service) -> anyhow::Result<Backend> {
    if let Some(path) = args.get("recipe") {
        let recipe = Recipe::load(Path::new(path))?;
        recipe.validate(&SiteSet::new(&svc.model_cfg))?;
        return Ok(Backend::recipe(recipe));
    }
    let mode = parse_mode(args)?;
    let choices = ["engine-fp32", "engine-int8", "pjrt-fp32", "pjrt-int8"];
    Ok(match args.get_choice("backend", &choices, "engine-int8")? {
        "engine-fp32" => Backend::EngineF32,
        "pjrt-fp32" => Backend::Runtime(RtPrecision::Fp32),
        "pjrt-int8" => Backend::Runtime(RtPrecision::Int8),
        _ => svc.int8_backend(mode)?,
    })
}

/// `--gemm-pool off|auto|N` — persistent GEMM worker-pool sizing
/// (absent flag = `Auto`, deferring to `QUANTNMT_GEMM_POOL` / the
/// thread budget).
fn parse_gemm_pool(args: &Args) -> anyhow::Result<quantnmt::gemm::PoolMode> {
    match args.get("gemm-pool") {
        None => Ok(quantnmt::gemm::PoolMode::Auto),
        Some(v) => quantnmt::gemm::parse_pool_mode(v)
            .ok_or_else(|| anyhow::anyhow!("unknown --gemm-pool '{v}' (valid: off|auto|N)")),
    }
}

fn parse_config(args: &Args, svc: &Service) -> anyhow::Result<ServiceConfig> {
    let policy = PolicyKind::parse_or(args.get("policy"), PolicyKind::FixedCount)?;
    Ok(ServiceConfig {
        backend: parse_backend(args, svc)?,
        sort: match args.get_choice("sort", &["unsorted", "words", "tokens"], "tokens")? {
            "unsorted" => SortOrder::Unsorted,
            "words" => SortOrder::Words,
            _ => SortOrder::Tokens,
        },
        batch_size: args.get_usize("batch", 64),
        policy,
        token_budget: args.get_usize("token-budget", DEFAULT_TOKEN_BUDGET),
        streams: args.get_usize("streams", 2),
        parallel: !args.flag("serial"),
        pin_cores: !args.flag("no-pin"),
        max_decode_len: args.get_usize("max-len", 56),
        gemm_threads: args.get_usize("gemm-threads", 0),
        gemm_pool: parse_gemm_pool(args)?,
    })
}

fn open_service(args: &Args) -> anyhow::Result<Service> {
    match args.get("artifacts") {
        Some(dir) => Service::open(dir.into()),
        None => Service::open_default(),
    }
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let svc = open_service(args)?;
    println!("artifacts:  {}", svc.dir.display());
    println!(
        "model:      d_model={} heads={} enc={} dec={} vocab={}",
        svc.model_cfg.d_model,
        svc.model_cfg.n_heads,
        svc.model_cfg.n_enc_layers,
        svc.model_cfg.n_dec_layers,
        svc.model_cfg.vocab_size
    );
    println!("params:     {}", svc.weights.param_count());
    println!("matmul sites: {}", svc.model_cfg.matmul_site_names().len());
    println!("class census: {:?}", svc.calibration.class_census());
    match &svc.aot_index {
        Some(idx) => {
            println!("AOT buckets:");
            for b in &idx.buckets {
                println!(
                    "  {:6} b{:<3} [{}x{}] {}",
                    b.precision.as_str(),
                    b.batch,
                    b.src_len,
                    b.tgt_len,
                    b.file.file_name().unwrap().to_string_lossy()
                );
            }
        }
        None => println!("AOT buckets: (none — run make artifacts)"),
    }
    println!(
        "platform:   {}",
        quantnmt::runtime::client::platform_info().unwrap_or_else(|e| format!("({e})"))
    );
    Ok(())
}

fn cmd_translate(args: &Args) -> anyhow::Result<()> {
    let svc = open_service(args)?;
    let cfg = parse_config(args, &svc)?;
    let lex = quantnmt::data::Lexicon::build(&Default::default());
    let ds = svc.dataset()?;
    let limit = args.get_usize("limit", 8);
    let pairs: Vec<_> = ds.test.into_iter().take(limit).collect();
    let (metrics, outputs) = svc.run(&pairs, &cfg)?;
    for (pair, out) in pairs.iter().zip(&outputs) {
        println!("src: {}", pair.text);
        println!("out: {}", lex.detokenize(out));
        let ok = out == &quantnmt::data::bleu::strip_special(&pair.ref_ids);
        println!("ref match: {}\n", if ok { "yes" } else { "NO" });
    }
    println!("{}", metrics.row());
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let svc = open_service(args)?;
    let cfg = parse_config(args, &svc)?;
    let ds = svc.dataset()?;
    let limit = args.get_usize("limit", ds.test.len());
    let (metrics, _) = svc.run(&ds.test[..limit.min(ds.test.len())], &cfg)?;
    println!("{}", metrics.row());
    Ok(())
}

fn parse_server_config(args: &Args, svc: &Service) -> anyhow::Result<ServerConfig> {
    Ok(ServerConfig {
        backend: parse_backend(args, svc)?,
        shards: args.get_usize("shards", 2),
        max_wait: Duration::from_secs_f64(args.get_f64("max-wait-ms", 20.0) / 1e3),
        token_budget: args.get_usize("token-budget", DEFAULT_TOKEN_BUDGET),
        max_batch_rows: args.get_usize("batch", 64),
        queue_capacity: args.get_usize("queue-cap", 256),
        max_src_len: None,
        pin_cores: !args.flag("no-pin"),
        max_decode_len: args.get_usize("max-len", 56),
        scheduler: Scheduler::parse_or(args.get("scheduler"), Scheduler::Batch)?,
        slots: args.get_usize("slots", 0),
        // 0 = unset: worst-case KV sizing (allocation can never fail)
        kv_budget_mb: match args.get_usize("kv-budget-mb", 0) {
            0 => None,
            mb => Some(mb),
        },
        gemm_threads: args.get_usize("gemm-threads", 0),
        gemm_pool: parse_gemm_pool(args)?,
        tenants: match args.get("tenants") {
            Some(path) => TenantSet::load(Path::new(path))?,
            None => TenantSet::single(),
        },
    })
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let svc = open_service(args)?;
    let cfg = parse_server_config(args, &svc)?;
    if let Some(addr) = args.get("listen") {
        return cmd_serve_net(args, &svc, cfg, addr);
    }
    let ds = svc.dataset()?;
    let limit = args.get_usize("limit", 512).min(ds.test.len());
    let rate = args.get_f64("rate", 100.0);
    let seed = args.get_usize("seed", 0x5EED) as u64;
    // with a multi-tenant spec the replay cycles requests through the
    // tenants so the weighted-fair/rate-limit path actually exercises
    let reqs = if cfg.tenants.len() > 1 {
        TranslateRequest::from_pairs_round_robin(&ds.test[..limit], cfg.tenants.len())
    } else {
        TranslateRequest::from_pairs(&ds.test[..limit])
    };
    let offsets = poisson_offsets(seed, reqs.len(), rate);
    println!(
        "replaying {} requests at {:.0} req/s (Poisson, seed {seed}) through {}",
        reqs.len(),
        rate,
        cfg.label()
    );
    let (metrics, responses, (submitted, shed)) =
        svc.serve(&cfg, |client| replay_trace(client, reqs, &offsets))?;
    println!("{}", metrics.row());
    let truncated = responses.iter().filter(|r| r.truncated).count();
    println!(
        "submitted {submitted}  shed {shed} (+{} oversize)  truncated {truncated}  \
         batches {}  utilization {:.1}%  wall {:.2}s",
        metrics.shed_oversize,
        metrics.batches,
        metrics.utilization * 100.0,
        metrics.wall_secs
    );
    if cfg.scheduler == Scheduler::Continuous {
        println!(
            "ttft p50/p90/p99 {:.1}/{:.1}/{:.1}ms  itl p50/p90/p99 {:.2}/{:.2}/{:.2}ms",
            metrics.ttft_latency.p50() * 1e3,
            metrics.ttft_latency.p90() * 1e3,
            metrics.ttft_latency.p99() * 1e3,
            metrics.inter_token_latency.p50() * 1e3,
            metrics.inter_token_latency.p90() * 1e3,
            metrics.inter_token_latency.p99() * 1e3,
        );
        let fills: Vec<String> = metrics
            .shard_fill
            .iter()
            .map(|f| format!("{:.1}%", f * 100.0))
            .collect();
        println!(
            "decode steps {}  slot occupancy {:.1}% (per shard: {})",
            metrics.decode_steps,
            metrics.slot_fill() * 100.0,
            fills.join(" "),
        );
        let page_highs: Vec<String> = metrics
            .shard_page_high
            .iter()
            .map(|f| format!("{:.1}%", f * 100.0))
            .collect();
        println!(
            "kv pages: occupancy {:.1}%  high-water {:.1}% of budget (per shard: {})",
            metrics.page_fill() * 100.0,
            metrics.page_high() * 100.0,
            page_highs.join(" "),
        );
    }
    Ok(())
}

/// `serve --listen ADDR`: bind the HTTP/SSE front end instead of
/// replaying an in-process trace.  Runs until `--serve-secs N` elapses
/// (default 30), then drains gracefully — every admitted request is
/// answered before the summary prints.
fn cmd_serve_net(
    args: &Args,
    svc: &Service,
    mut cfg: ServerConfig,
    addr: &str,
) -> anyhow::Result<()> {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    // token streaming needs iteration-level scheduling; only an
    // explicit --scheduler batch (rejected downstream) overrides this
    if args.get("scheduler").is_none() {
        cfg.scheduler = Scheduler::Continuous;
    }
    let listener = std::net::TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let secs = args.get_f64("serve-secs", 30.0);
    println!("listening on http://{local} ({}) for {secs:.0}s", cfg.label());
    let stop = Arc::new(AtomicBool::new(false));
    {
        let stop = Arc::clone(&stop);
        // detached timer: the accept loop polls the flag; process exit
        // reaps the thread if serve_net errors out early
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_secs_f64(secs));
            stop.store(true, Ordering::Release);
        });
    }
    let (metrics, responses) = svc.serve_net(&cfg, listener, stop)?;
    println!("{}", metrics.row());
    let truncated = responses.iter().filter(|r| r.truncated).count();
    println!(
        "served {} responses  cancelled {}  truncated {truncated}  wall {:.2}s",
        responses.len(),
        metrics.cancelled,
        metrics.wall_secs
    );
    Ok(())
}

fn cmd_ladder(args: &Args) -> anyhow::Result<()> {
    let svc = open_service(args)?;
    let ds = svc.dataset()?;
    let limit = args.get_usize("limit", 512);
    let pairs = &ds.test[..limit.min(ds.test.len())];
    // derive the symmetric-mode recipe once; every INT8 rung shares it
    let int8 = svc.int8_backend(CalibrationMode::Symmetric)?;
    // the Fig-8a configuration ladder, out-of-the-box -> fully optimized
    let ladder: Vec<ServiceConfig> = vec![
        ServiceConfig {
            backend: Backend::EngineF32,
            sort: SortOrder::Words,
            parallel: false,
            ..Default::default()
        },
        ServiceConfig {
            backend: Backend::EngineF32,
            sort: SortOrder::Tokens,
            parallel: false,
            ..Default::default()
        },
        ServiceConfig {
            backend: Backend::EngineF32,
            sort: SortOrder::Tokens,
            streams: 2,
            parallel: true,
            ..Default::default()
        },
        ServiceConfig {
            backend: int8.clone(),
            sort: SortOrder::Words,
            parallel: false,
            ..Default::default()
        },
        ServiceConfig {
            backend: int8.clone(),
            sort: SortOrder::Tokens,
            parallel: false,
            ..Default::default()
        },
        ServiceConfig {
            backend: int8.clone(),
            sort: SortOrder::Tokens,
            streams: 2,
            parallel: true,
            ..Default::default()
        },
        ServiceConfig {
            backend: int8.clone(),
            sort: SortOrder::Tokens,
            streams: 4,
            parallel: true,
            ..Default::default()
        },
        // + bin-packing batch shaping (the paper's §5.6 technique)
        ServiceConfig {
            backend: int8,
            sort: SortOrder::Tokens,
            streams: 4,
            parallel: true,
            policy: PolicyKind::BinPack,
            ..Default::default()
        },
    ];
    let mut base = None;
    for cfg in &ladder {
        let (m, _) = svc.run(pairs, cfg)?;
        let rate = m.sentences_per_sec();
        let base_rate = *base.get_or_insert(rate);
        println!("{}   x{:.2}", m.row(), rate / base_rate);
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    let svc = open_service(args)?;
    let table = &svc.calibration;
    println!(
        "{:28} {:9} {:>10} {:>10} {:>20}",
        "site", "class", "|range|", "T_sym", "T_indep"
    );
    for (name, cal) in &table.sites {
        println!(
            "{:28} {:9} {:>10.3} {:>10.3} ({:>8.3},{:>8.3})",
            name,
            cal.class.as_str(),
            cal.max.max(-cal.min),
            cal.thr_symmetric,
            cal.thr_independent.0,
            cal.thr_independent.1,
        );
    }
    println!("census: {:?}", table.class_census());
    Ok(())
}

/// `quantnmt recipe derive|show|diff` — the recipe lifecycle without
/// touching the serving path: derive from calibration (artifacts or a
/// deterministic `--synthetic` table), pretty-print + census-validate,
/// and diff two saved recipes site by site.
fn cmd_recipe(args: &Args) -> anyhow::Result<()> {
    let sub = args.positional.get(1).map(String::as_str).unwrap_or("");
    match sub {
        "derive" => {
            let mode = parse_mode(args)?;
            let (table, model_cfg) = if args.flag("synthetic") {
                let cfg = ModelConfig::default();
                let seed = args.get_usize("seed", 0xC0DE) as u64;
                (SiteTable::synthetic(&cfg, seed), cfg)
            } else {
                let svc = open_service(args)?;
                (svc.calibration, svc.model_cfg)
            };
            let sites = SiteSet::new(&model_cfg);
            let mut builder = RecipeBuilder::new(&table, &sites, mode);
            if args.flag("quantize-sparse") {
                builder = builder.quantize_sparse(true);
            }
            // application order is fixed (the flag parser cannot see
            // interleaving): --int8 re-modes first, then --fp32 — so a
            // narrow FP32 exception always wins over a broad re-mode,
            // matching the paper's fallback-has-the-last-word policy
            if let Some(ov) = args.get("int8") {
                for s in ov.split(',').filter(|s| !s.trim().is_empty()) {
                    let (sel, m) = s.split_once('=').unwrap_or((s, mode.as_str()));
                    let m = CalibrationMode::from_str(m.trim()).ok_or_else(|| {
                        anyhow::anyhow!("unknown calibration mode '{}' in --int8", m.trim())
                    })?;
                    builder = builder.with_mode(sel.trim(), m);
                }
            }
            if let Some(sel) = args.get("fp32") {
                for s in sel.split(',').filter(|s| !s.trim().is_empty()) {
                    builder = builder.force_fp32(s.trim());
                }
            }
            // fully-integer decision kinds: the broad sugar first,
            // then narrow glob refinements on top
            if args.flag("fully-integer") {
                builder = builder.fully_integer();
            }
            if let Some(sel) = args.get("fused") {
                for s in sel.split(',').filter(|s| !s.trim().is_empty()) {
                    builder = builder.requant_fused(s.trim());
                }
            }
            if let Some(sel) = args.get("per-channel") {
                for s in sel.split(',').filter(|s| !s.trim().is_empty()) {
                    builder = builder.per_channel(s.trim());
                }
            }
            if let Some(sel) = args.get("integer-ln") {
                for s in sel.split(',').filter(|s| !s.trim().is_empty()) {
                    builder = builder.integer_ln(s.trim());
                }
            }
            if let Some(sel) = args.get("integer-softmax") {
                for s in sel.split(',').filter(|s| !s.trim().is_empty()) {
                    builder = builder.integer_softmax(s.trim());
                }
            }
            if let Some(name) = args.get("name") {
                builder = builder.name(name);
            }
            let recipe = builder.build()?;
            let fused = recipe.iter().filter(|rs| rs.decision.is_fused()).count();
            eprintln!(
                "derived recipe '{}': {} int8 ({} fused) / {} fp32 sites, \
                 {} integer op flips (hash {:016x})",
                recipe.id(),
                recipe.int8_site_count(),
                fused,
                recipe.len() - recipe.int8_site_count(),
                recipe.ops_iter().count(),
                recipe.content_hash()
            );
            match args.get("out") {
                Some(path) => {
                    recipe.save(Path::new(path))?;
                    eprintln!("wrote {path}");
                }
                None => println!("{}", recipe.to_json()),
            }
            Ok(())
        }
        "show" => {
            let path = args
                .positional
                .get(2)
                .ok_or_else(|| anyhow::anyhow!("usage: quantnmt recipe show <recipe.json>"))?;
            let recipe = Recipe::load(Path::new(path))?;
            // census source: an explicit --artifacts dir makes
            // validation a hard gate; otherwise the census is guessed
            // (default artifacts dir, else ModelConfig::default) and a
            // mismatch is reported as a warning — pretty-printing a
            // recipe for a different model must still work
            let explicit = args.get("artifacts").is_some();
            let model_cfg = match args.get("artifacts") {
                Some(dir) => ModelConfig::load(&Path::new(dir).join("config.json"))?,
                // config.json alone carries the census; don't pay a
                // full Service load (weights + calibration) to print
                None => ModelConfig::load(&quantnmt::default_artifacts_dir().join("config.json"))
                    .unwrap_or_default(),
            };
            let sites = SiteSet::new(&model_cfg);
            println!(
                "recipe '{}' ({} sites, hash {:016x})",
                recipe.id(),
                recipe.len(),
                recipe.content_hash()
            );
            for rs in recipe.iter() {
                println!("  {:20} {}", rs.site, rs.decision);
            }
            for op in recipe.ops_iter() {
                println!("  {:20} {}", op.site, op.kind.as_str());
            }
            println!(
                "{} int8 / {} fp32 sites, {} integer op flips",
                recipe.int8_site_count(),
                recipe.len() - recipe.int8_site_count(),
                recipe.ops_iter().count(),
            );
            match recipe.validate(&sites) {
                Ok(()) => println!("validated against the {}-site census", sites.len()),
                Err(e) if explicit => return Err(e),
                Err(e) => eprintln!(
                    "warning: does not match the guessed {}-site census ({e}); \
                     pass --artifacts DIR to validate against the right model",
                    sites.len()
                ),
            }
            Ok(())
        }
        "diff" => {
            let (a, b) = match (args.positional.get(2), args.positional.get(3)) {
                (Some(a), Some(b)) => (a, b),
                _ => anyhow::bail!("usage: quantnmt recipe diff <a.json> <b.json>"),
            };
            let ra = Recipe::load(Path::new(a))?;
            let rb = Recipe::load(Path::new(b))?;
            let diff = ra.diff(&rb);
            println!(
                "'{}' vs '{}': {} site(s) differ",
                ra.id(),
                rb.id(),
                diff.len()
            );
            for d in &diff {
                println!(
                    "  {:20} {}  ->  {}",
                    d.site,
                    d.left.as_deref().unwrap_or("(absent)"),
                    d.right.as_deref().unwrap_or("(absent)")
                );
            }
            Ok(())
        }
        other => anyhow::bail!("unknown recipe subcommand '{other}' (expected derive|show|diff)"),
    }
}

fn cmd_graph_stats(args: &Args) -> anyhow::Result<()> {
    use quantnmt::graph::ir::{transformer_graph, GraphConfig};
    use quantnmt::graph::passes::plan_all;
    use quantnmt::graph::{naive_quantize, optimized_quantize};
    use quantnmt::model::{ModelConfig, SiteSet};
    let g = transformer_graph(GraphConfig::default());
    let plan = plan_all(&g);
    let (naive, ns) = naive_quantize(&g, &plan);
    let (opt, os) = optimized_quantize(&g, &plan);
    println!("fp32 graph:       {} nodes", g.nodes.len());
    println!("naive quantized:  {} nodes (Fig 1 form)", naive.nodes.len());
    println!("optimized:        {} nodes (Fig 5 form)", opt.nodes.len());
    println!("\nnaive census:     {:?}", naive.op_census());
    println!("\noptimized census: {:?}", opt.op_census());
    println!("\nops added naive: {:?}", ns.ops_added);
    println!("ops added opt:   {:?}", os.ops_added);
    if args.flag("per-site") {
        // the engine's interned dispatch table, straight from the same
        // census the graph IR carries (cross-checked, so it cannot lie)
        let cfg = ModelConfig::default();
        let sites = SiteSet::new(&cfg);
        sites.cross_check_graph(&cfg)?;
        println!("\ninterned MatMul sites (SiteId -> operand):");
        for (id, name) in sites.iter() {
            match cfg.weight_for_site(name) {
                Some(w) => println!("  {:>3}  {:16} weight {w}", id.0, name),
                None => println!("  {:>3}  {:16} dynamic (activation x activation)", id.0, name),
            }
        }
        println!(
            "{} sites interned; graph IR census cross-check OK",
            sites.len()
        );
    }
    Ok(())
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("info");
    let result = match cmd {
        "info" => cmd_info(&args),
        "translate" => cmd_translate(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "ladder" => cmd_ladder(&args),
        "calibrate" => cmd_calibrate(&args),
        "recipe" => cmd_recipe(&args),
        "graph-stats" => cmd_graph_stats(&args),
        other => {
            eprintln!("unknown command '{other}'");
            eprintln!(
                "usage: quantnmt [info|translate|run|serve|ladder|calibrate|recipe|graph-stats]"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
