//! quantnmt CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!
//! ```text
//! quantnmt info                         artifact + platform summary
//! quantnmt translate  [--limit N]       translate test sentences, show text
//! quantnmt run        [--streams N]     offline corpus throughput run (one Fig-8 bar)
//! quantnmt serve      [--shards N]      online server: replay a Poisson trace through
//!                                       the dynamic batcher, report latency percentiles
//! quantnmt ladder                       the full Fig-8 configuration ladder
//! quantnmt calibrate                    print the calibration table (§4.2)
//! quantnmt graph-stats [--per-site]     §5.5 op-census of naive vs optimized passes;
//!                                       --per-site prints the interned MatMul site
//!                                       table (SiteId -> weight) cross-checked
//!                                       against the graph IR census
//! ```
//!
//! Common flags: `--artifacts DIR`, `--backend engine-fp32|engine-int8|pjrt-fp32|pjrt-int8`,
//! `--mode naive|symmetric|independent|conjugate`, `--batch N`, `--streams N`,
//! `--sort unsorted|words|tokens`, `--policy fixed|token-budget|bin-pack`,
//! `--token-budget N` (padded-token budget per batch for the budget
//! policies and the online batcher), `--serial`, `--no-pin`, `--limit N`.
//!
//! `serve` flags: `--shards N` (worker streams), `--max-wait-ms MS`
//! (batching deadline), `--token-budget N`, `--batch N` (row cap),
//! `--rate R` (offered load, req/s), `--queue-cap N` (admission bound),
//! `--seed S` (arrival trace seed), `--limit N` (requests to replay),
//! `--max-len N` (decode-length cap, default 56).

use quantnmt::coordinator::server::{poisson_offsets, replay_trace, TranslateRequest};
use quantnmt::coordinator::service::DEFAULT_TOKEN_BUDGET;
use quantnmt::coordinator::{Backend, ServerConfig, Service, ServiceConfig};
use quantnmt::data::sorting::SortOrder;
use quantnmt::pipeline::policy::PolicyKind;
use quantnmt::quant::calibrate::CalibrationMode;
use quantnmt::runtime::RtPrecision;
use quantnmt::util::cli::Args;
use std::time::Duration;

fn parse_backend(args: &Args) -> Backend {
    let mode = CalibrationMode::from_str(args.get_or("mode", "symmetric"))
        .unwrap_or(CalibrationMode::Symmetric);
    match args.get_or("backend", "engine-int8") {
        "engine-fp32" => Backend::EngineF32,
        "engine-int8" => Backend::EngineInt8(mode),
        "pjrt-fp32" => Backend::Runtime(RtPrecision::Fp32),
        "pjrt-int8" => Backend::Runtime(RtPrecision::Int8),
        other => {
            eprintln!("unknown backend '{other}', using engine-int8");
            Backend::EngineInt8(mode)
        }
    }
}

fn parse_config(args: &Args) -> ServiceConfig {
    let policy = PolicyKind::parse_or(args.get("policy"), PolicyKind::FixedCount);
    ServiceConfig {
        backend: parse_backend(args),
        sort: match args.get_or("sort", "tokens") {
            "unsorted" => SortOrder::Unsorted,
            "words" => SortOrder::Words,
            _ => SortOrder::Tokens,
        },
        batch_size: args.get_usize("batch", 64),
        policy,
        token_budget: args.get_usize("token-budget", DEFAULT_TOKEN_BUDGET),
        streams: args.get_usize("streams", 2),
        parallel: !args.flag("serial"),
        pin_cores: !args.flag("no-pin"),
        max_decode_len: args.get_usize("max-len", 56),
    }
}

fn open_service(args: &Args) -> anyhow::Result<Service> {
    match args.get("artifacts") {
        Some(dir) => Service::open(dir.into()),
        None => Service::open_default(),
    }
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let svc = open_service(args)?;
    println!("artifacts:  {}", svc.dir.display());
    println!(
        "model:      d_model={} heads={} enc={} dec={} vocab={}",
        svc.model_cfg.d_model,
        svc.model_cfg.n_heads,
        svc.model_cfg.n_enc_layers,
        svc.model_cfg.n_dec_layers,
        svc.model_cfg.vocab_size
    );
    println!("params:     {}", svc.weights.param_count());
    println!("matmul sites: {}", svc.model_cfg.matmul_site_names().len());
    println!("class census: {:?}", svc.calibration.class_census());
    match &svc.aot_index {
        Some(idx) => {
            println!("AOT buckets:");
            for b in &idx.buckets {
                println!(
                    "  {:6} b{:<3} [{}x{}] {}",
                    b.precision.as_str(),
                    b.batch,
                    b.src_len,
                    b.tgt_len,
                    b.file.file_name().unwrap().to_string_lossy()
                );
            }
        }
        None => println!("AOT buckets: (none — run make artifacts)"),
    }
    println!(
        "platform:   {}",
        quantnmt::runtime::client::platform_info().unwrap_or_else(|e| format!("({e})"))
    );
    Ok(())
}

fn cmd_translate(args: &Args) -> anyhow::Result<()> {
    let svc = open_service(args)?;
    let cfg = parse_config(args);
    let lex = quantnmt::data::Lexicon::build(&Default::default());
    let ds = svc.dataset()?;
    let limit = args.get_usize("limit", 8);
    let pairs: Vec<_> = ds.test.into_iter().take(limit).collect();
    let (metrics, outputs) = svc.run(&pairs, &cfg)?;
    for (pair, out) in pairs.iter().zip(&outputs) {
        println!("src: {}", pair.text);
        println!("out: {}", lex.detokenize(out));
        let ok = out == &quantnmt::data::bleu::strip_special(&pair.ref_ids);
        println!("ref match: {}\n", if ok { "yes" } else { "NO" });
    }
    println!("{}", metrics.row());
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let svc = open_service(args)?;
    let cfg = parse_config(args);
    let ds = svc.dataset()?;
    let limit = args.get_usize("limit", ds.test.len());
    let (metrics, _) = svc.run(&ds.test[..limit.min(ds.test.len())], &cfg)?;
    println!("{}", metrics.row());
    Ok(())
}

fn parse_server_config(args: &Args) -> ServerConfig {
    ServerConfig {
        backend: parse_backend(args),
        shards: args.get_usize("shards", 2),
        max_wait: Duration::from_secs_f64(args.get_f64("max-wait-ms", 20.0) / 1e3),
        token_budget: args.get_usize("token-budget", DEFAULT_TOKEN_BUDGET),
        max_batch_rows: args.get_usize("batch", 64),
        queue_capacity: args.get_usize("queue-cap", 256),
        max_src_len: None,
        pin_cores: !args.flag("no-pin"),
        max_decode_len: args.get_usize("max-len", 56),
    }
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let svc = open_service(args)?;
    let cfg = parse_server_config(args);
    let ds = svc.dataset()?;
    let limit = args.get_usize("limit", 512).min(ds.test.len());
    let rate = args.get_f64("rate", 100.0);
    let seed = args.get_usize("seed", 0x5EED) as u64;
    let reqs = TranslateRequest::from_pairs(&ds.test[..limit]);
    let offsets = poisson_offsets(seed, reqs.len(), rate);
    println!(
        "replaying {} requests at {:.0} req/s (Poisson, seed {seed}) through {}",
        reqs.len(),
        rate,
        cfg.label()
    );
    let (metrics, _responses, (submitted, shed)) =
        svc.serve(&cfg, |client| replay_trace(client, reqs, &offsets))?;
    println!("{}", metrics.row());
    println!(
        "submitted {submitted}  shed {shed}  batches {}  utilization {:.1}%  wall {:.2}s",
        metrics.batches,
        metrics.utilization * 100.0,
        metrics.wall_secs
    );
    Ok(())
}

fn cmd_ladder(args: &Args) -> anyhow::Result<()> {
    let svc = open_service(args)?;
    let ds = svc.dataset()?;
    let limit = args.get_usize("limit", 512);
    let pairs = &ds.test[..limit.min(ds.test.len())];
    let mode = CalibrationMode::Symmetric;
    // the Fig-8a configuration ladder, out-of-the-box -> fully optimized
    let ladder: Vec<ServiceConfig> = vec![
        ServiceConfig {
            backend: Backend::EngineF32,
            sort: SortOrder::Words,
            parallel: false,
            ..Default::default()
        },
        ServiceConfig {
            backend: Backend::EngineF32,
            sort: SortOrder::Tokens,
            parallel: false,
            ..Default::default()
        },
        ServiceConfig {
            backend: Backend::EngineF32,
            sort: SortOrder::Tokens,
            streams: 2,
            parallel: true,
            ..Default::default()
        },
        ServiceConfig {
            backend: Backend::EngineInt8(mode),
            sort: SortOrder::Words,
            parallel: false,
            ..Default::default()
        },
        ServiceConfig {
            backend: Backend::EngineInt8(mode),
            sort: SortOrder::Tokens,
            parallel: false,
            ..Default::default()
        },
        ServiceConfig {
            backend: Backend::EngineInt8(mode),
            sort: SortOrder::Tokens,
            streams: 2,
            parallel: true,
            ..Default::default()
        },
        ServiceConfig {
            backend: Backend::EngineInt8(mode),
            sort: SortOrder::Tokens,
            streams: 4,
            parallel: true,
            ..Default::default()
        },
        // + bin-packing batch shaping (the paper's §5.6 technique)
        ServiceConfig {
            backend: Backend::EngineInt8(mode),
            sort: SortOrder::Tokens,
            streams: 4,
            parallel: true,
            policy: PolicyKind::BinPack,
            ..Default::default()
        },
    ];
    let mut base = None;
    for cfg in &ladder {
        let (m, _) = svc.run(pairs, cfg)?;
        let rate = m.sentences_per_sec();
        let base_rate = *base.get_or_insert(rate);
        println!("{}   x{:.2}", m.row(), rate / base_rate);
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    let svc = open_service(args)?;
    let table = &svc.calibration;
    println!(
        "{:28} {:9} {:>10} {:>10} {:>20}",
        "site", "class", "|range|", "T_sym", "T_indep"
    );
    for (name, cal) in &table.sites {
        println!(
            "{:28} {:9} {:>10.3} {:>10.3} ({:>8.3},{:>8.3})",
            name,
            cal.class.as_str(),
            cal.max.max(-cal.min),
            cal.thr_symmetric,
            cal.thr_independent.0,
            cal.thr_independent.1,
        );
    }
    println!("census: {:?}", table.class_census());
    Ok(())
}

fn cmd_graph_stats(args: &Args) -> anyhow::Result<()> {
    use quantnmt::graph::ir::{transformer_graph, GraphConfig};
    use quantnmt::graph::passes::plan_all;
    use quantnmt::graph::{naive_quantize, optimized_quantize};
    use quantnmt::model::{ModelConfig, SiteSet};
    let g = transformer_graph(GraphConfig::default());
    let plan = plan_all(&g);
    let (naive, ns) = naive_quantize(&g, &plan);
    let (opt, os) = optimized_quantize(&g, &plan);
    println!("fp32 graph:       {} nodes", g.nodes.len());
    println!("naive quantized:  {} nodes (Fig 1 form)", naive.nodes.len());
    println!("optimized:        {} nodes (Fig 5 form)", opt.nodes.len());
    println!("\nnaive census:     {:?}", naive.op_census());
    println!("\noptimized census: {:?}", opt.op_census());
    println!("\nops added naive: {:?}", ns.ops_added);
    println!("ops added opt:   {:?}", os.ops_added);
    if args.flag("per-site") {
        // the engine's interned dispatch table, straight from the same
        // census the graph IR carries (cross-checked, so it cannot lie)
        let cfg = ModelConfig::default();
        let sites = SiteSet::new(&cfg);
        sites.cross_check_graph(&cfg)?;
        println!("\ninterned MatMul sites (SiteId -> operand):");
        for (id, name) in sites.iter() {
            match cfg.weight_for_site(name) {
                Some(w) => println!("  {:>3}  {:16} weight {w}", id.0, name),
                None => println!("  {:>3}  {:16} dynamic (activation x activation)", id.0, name),
            }
        }
        println!(
            "{} sites interned; graph IR census cross-check OK",
            sites.len()
        );
    }
    Ok(())
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("info");
    let result = match cmd {
        "info" => cmd_info(&args),
        "translate" => cmd_translate(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "ladder" => cmd_ladder(&args),
        "calibrate" => cmd_calibrate(&args),
        "graph-stats" => cmd_graph_stats(&args),
        other => {
            eprintln!("unknown command '{other}'");
            eprintln!("usage: quantnmt [info|translate|run|serve|ladder|calibrate|graph-stats]");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
