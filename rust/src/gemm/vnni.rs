//! AVX-512 VNNI `vpdpbusd` GEMM kernels.
//!
//! `vpdpbusd dst, src1, src2` computes, per i32 lane,
//! `dst += sum_{q=0..4} src1.u8[4i+q] * src2.s8[4i+q]` — 64 byte-MACs
//! per instruction.  This is the exact instruction the paper's MKL
//! kernel leans on (§2, §5.2).  Mapping to our `A_s8 [m,k] x B_u8 [k,n]`:
//! the *unsigned* operand is B and the *signed* operand is A, so each
//! instruction takes 16 j-lanes of B quads against a broadcast A quad.
//! B is repacked into the shared [`PackedB`] panel (module `pack`).
//!
//! Two kernels live here:
//!
//! * [`igemm_vnni`] — the original per-row macro-loop.  It re-streams
//!   the whole packed B panel once per A row, so for m rows the panel
//!   crosses the cache hierarchy m times.  Kept as the bench baseline
//!   ("vnni-row" in `benches/gemm.rs`) and as a second reference
//!   implementation.
//! * [`igemm_vnni_tiled`] — the BLIS-style macro-kernel: an
//!   MR x (2 zmm) register tile ([`MR`] = 6 rows x 32 lanes = 12 zmm
//!   accumulators) amortizes each packed-B cache line over MR rows,
//!   wrapped in KC (`KC_QUADS`) x NC (`NC_LANES`) cache blocking with a
//!   quad-packed A panel ([`pack_a`]).  Column range `[j0, j1)` makes
//!   it stripe-parallel (`dispatch::run_cols`); the row-range twin
//!   [`igemm_vnni_tiled_rows`] serves tall-skinny shapes
//!   (`dispatch::run_rows`) from the same A panel.
//!
//! Feature-gated at runtime: [`vnni_available`] (dispatch falls down
//! the `IsaLevel` ladder on machines without AVX-512 VNNI).

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

pub use super::pack::{PackedB, VNNI_LANES};
#[cfg(target_arch = "x86_64")]
use super::{KC_QUADS, NC_LANES};

/// Accumulator tile rows for [`igemm_vnni_tiled`]: 6 rows x 2 zmm
/// accumulators = 12 of the 32 zmm registers, leaving room for the 2
/// B vectors and broadcasts.
pub const MR: usize = 6;

/// Runtime check for AVX-512 VNNI (+ the AVX-512F/BW baseline we use).
pub fn vnni_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx512vnni")
            && std::arch::is_x86_feature_detected!("avx512f")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Pack `a [m, k]` (s8) for the tiled kernel: one broadcast-ready i32
/// per (quad, row) holding 4 consecutive signed k-bytes, zero-padded at
/// the k tail (neutral before the zero-point correction).  Quad-major
/// layout `out[quad*m + row]` so the micro-kernel reads MR consecutive
/// words per k-step.
pub fn pack_a(a: &[i8], m: usize, k: usize, out: &mut Vec<i32>) {
    assert_eq!(a.len(), m * k);
    let kp = k.div_ceil(4);
    out.clear();
    out.resize(kp * m, 0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for quad in 0..kp {
            let base = quad * 4;
            let take = (k - base).min(4);
            let mut qb = [0u8; 4];
            for (x, &av) in qb.iter_mut().zip(&arow[base..base + take]) {
                *x = av as u8;
            }
            out[quad * m + i] = i32::from_le_bytes(qb);
        }
    }
}

/// `c[m,n] += a[m,k] x B` via vpdpbusd, one row at a time. Caller must
/// zero `c` first and have checked [`vnni_available`].
///
/// # Safety
/// Requires AVX-512F + AVX-512VNNI (checked by the caller).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
pub unsafe fn igemm_vnni(m: usize, k: usize, a: &[i8], bp: &PackedB, c: &mut [i32]) {
    let n = bp.n;
    let np = bp.np;
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    debug_assert_eq!(bp.k, k);

    // a row padded to quads on the stack when k % 4 != 0
    let kq = k / 4;
    let k_tail = k % 4;

    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let mut jt = 0;
        while jt < n {
            let lanes = VNNI_LANES.min(n - jt);
            let mut acc = _mm512_setzero_si512();
            // full quads
            for quad in 0..kq {
                // broadcast 4 signed A bytes to every lane
                let a_quad = i32::from_le_bytes([
                    arow[quad * 4] as u8,
                    arow[quad * 4 + 1] as u8,
                    arow[quad * 4 + 2] as u8,
                    arow[quad * 4 + 3] as u8,
                ]);
                let av = _mm512_set1_epi32(a_quad);
                let bptr = bp.data.as_ptr().add(quad * np * 4 + jt * 4) as *const i32;
                let bv = _mm512_loadu_si512(bptr as *const _);
                // unsigned operand = B, signed operand = A
                acc = _mm512_dpbusd_epi32(acc, bv, av);
            }
            // ragged k tail (0..3 remaining rows): pad A quad with zeros
            if k_tail != 0 {
                let mut quad_bytes = [0u8; 4];
                for (q, qb) in quad_bytes.iter_mut().enumerate().take(k_tail) {
                    *qb = arow[kq * 4 + q] as u8;
                }
                let av = _mm512_set1_epi32(i32::from_le_bytes(quad_bytes));
                let bptr = bp.data.as_ptr().add(kq * np * 4 + jt * 4) as *const i32;
                let bv = _mm512_loadu_si512(bptr as *const _);
                acc = _mm512_dpbusd_epi32(acc, bv, av);
            }
            // store (masked on the ragged right edge)
            let cptr = c.as_mut_ptr().add(i * n + jt);
            if lanes == VNNI_LANES {
                let prev = _mm512_loadu_si512(cptr as *const _);
                _mm512_storeu_si512(cptr as *mut _, _mm512_add_epi32(prev, acc));
            } else {
                let mask: u16 = (1u16 << lanes) - 1;
                let prev = _mm512_maskz_loadu_epi32(mask, cptr);
                _mm512_mask_storeu_epi32(cptr, mask, _mm512_add_epi32(prev, acc));
            }
            jt += VNNI_LANES;
        }
    }
}

#[cfg(not(target_arch = "x86_64"))]
pub unsafe fn igemm_vnni(_m: usize, _k: usize, _a: &[i8], _bp: &PackedB, _c: &mut [i32]) {
    unreachable!("vnni_available() is false on this arch")
}

/// Tiled VNNI macro-kernel over columns `[j0, j1)` of the packed panel;
/// A pre-packed by [`pack_a`].  Overwrites C (no pre-zero needed): the
/// first k-block stores, later blocks accumulate.
///
/// # Safety
/// Requires AVX-512F/BW/VNNI (callers dispatch via [`vnni_available`]).
/// `cbase` must point at an `m * bp.n` i32 buffer; concurrent callers
/// must write disjoint `[j0, j1)` ranges.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
pub unsafe fn igemm_vnni_tiled(
    m: usize,
    apack: &[i32],
    bp: &PackedB,
    cbase: *mut i32,
    j0: usize,
    j1: usize,
) {
    tiled_rect(m, apack, bp, cbase, 0, m, j0, j1)
}

/// Row-stripe twin of [`igemm_vnni_tiled`]: rows `[i0, i1)` over the
/// full column range, for tall-skinny shapes (`dispatch::run_rows`).
/// The quad-major A panel ([`pack_a`]) is indexed by absolute row, so a
/// row sub-range needs no repacking; row grouping never changes any
/// element's k-summation order, so the output is bit-identical to the
/// column-striped and single-threaded paths.
///
/// # Safety
/// As [`igemm_vnni_tiled`], with concurrent callers writing disjoint
/// `[i0, i1)` row ranges instead.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
pub unsafe fn igemm_vnni_tiled_rows(
    m: usize,
    apack: &[i32],
    bp: &PackedB,
    cbase: *mut i32,
    i0: usize,
    i1: usize,
) {
    tiled_rect(m, apack, bp, cbase, i0, i1, 0, bp.n)
}

/// Shared macro-loop over the `[i0, i1) x [j0, j1)` output rectangle.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
#[allow(clippy::too_many_arguments)]
unsafe fn tiled_rect(
    m: usize,
    apack: &[i32],
    bp: &PackedB,
    cbase: *mut i32,
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
) {
    debug_assert_eq!(apack.len(), bp.kp * m);
    debug_assert!(i1 <= m);
    debug_assert!(j1 <= bp.n);
    let kp = bp.kp;
    let np = bp.np;
    let mut jc = j0;
    while jc < j1 {
        let jl = (jc + NC_LANES).min(j1);
        let mut pc = 0;
        loop {
            let kq = (kp - pc).min(KC_QUADS);
            let first = pc == 0;
            let mut i = i0;
            while i < i1 {
                let mr = (i1 - i).min(MR);
                let mut jt = jc;
                // 2-zmm (32-lane) tiles while a full pair is loadable
                while jt < jl && jt + 32 <= np {
                    match mr {
                        1 => tile32::<1>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                        2 => tile32::<2>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                        3 => tile32::<3>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                        4 => tile32::<4>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                        5 => tile32::<5>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                        _ => tile32::<6>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                    }
                    jt += 32;
                }
                // np % 32 == 16 leaves a single-zmm column tail
                if jt < jl {
                    match mr {
                        1 => tile16::<1>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                        2 => tile16::<2>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                        3 => tile16::<3>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                        4 => tile16::<4>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                        5 => tile16::<5>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                        _ => tile16::<6>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                    }
                }
                i += mr;
            }
            pc += kq;
            if pc >= kp {
                break;
            }
        }
        jc = jl;
    }
}

/// One MR x 32-lane (2 zmm) register tile over quads `[pc, pc+kq)`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
#[allow(clippy::too_many_arguments)]
unsafe fn tile32<const R: usize>(
    m: usize,
    apack: &[i32],
    bp: &PackedB,
    pc: usize,
    kq: usize,
    i: usize,
    jt: usize,
    cbase: *mut i32,
    jlim: usize,
    first: bool,
) {
    let np = bp.np;
    let n = bp.n;
    let bdata = bp.data.as_ptr();
    let mut acc0 = [_mm512_setzero_si512(); R];
    let mut acc1 = [_mm512_setzero_si512(); R];
    for quad in pc..pc + kq {
        let bptr = bdata.add((quad * np + jt) * 4);
        let bv0 = _mm512_loadu_si512(bptr as *const _);
        let bv1 = _mm512_loadu_si512(bptr.add(64) as *const _);
        let ap = apack.as_ptr().add(quad * m + i);
        for r in 0..R {
            let av = _mm512_set1_epi32(*ap.add(r));
            acc0[r] = _mm512_dpbusd_epi32(acc0[r], bv0, av);
            acc1[r] = _mm512_dpbusd_epi32(acc1[r], bv1, av);
        }
    }
    for r in 0..R {
        let row = cbase.add((i + r) * n);
        store16(row.add(jt), acc0[r], jlim as isize - jt as isize, first);
        store16(row.add(jt + 16), acc1[r], jlim as isize - jt as isize - 16, first);
    }
}

/// One MR x 16-lane (1 zmm) register tile (np % 32 == 16 column tail).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
#[allow(clippy::too_many_arguments)]
unsafe fn tile16<const R: usize>(
    m: usize,
    apack: &[i32],
    bp: &PackedB,
    pc: usize,
    kq: usize,
    i: usize,
    jt: usize,
    cbase: *mut i32,
    jlim: usize,
    first: bool,
) {
    let np = bp.np;
    let n = bp.n;
    let bdata = bp.data.as_ptr();
    let mut acc = [_mm512_setzero_si512(); R];
    for quad in pc..pc + kq {
        let bptr = bdata.add((quad * np + jt) * 4);
        let bv = _mm512_loadu_si512(bptr as *const _);
        let ap = apack.as_ptr().add(quad * m + i);
        for r in 0..R {
            let av = _mm512_set1_epi32(*ap.add(r));
            acc[r] = _mm512_dpbusd_epi32(acc[r], bv, av);
        }
    }
    for r in 0..R {
        let row = cbase.add((i + r) * n);
        store16(row.add(jt), acc[r], jlim as isize - jt as isize, first);
    }
}

/// Store/accumulate 16 lanes at `p`, clipped to `valid` columns.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
unsafe fn store16(p: *mut i32, v: __m512i, valid: isize, first: bool) {
    if valid >= 16 {
        if first {
            _mm512_storeu_si512(p as *mut _, v);
        } else {
            let prev = _mm512_loadu_si512(p as *const _);
            _mm512_storeu_si512(p as *mut _, _mm512_add_epi32(prev, v));
        }
    } else if valid > 0 {
        let mask: u16 = (1u16 << valid) - 1;
        if first {
            _mm512_mask_storeu_epi32(p, mask, v);
        } else {
            let prev = _mm512_maskz_loadu_epi32(mask, p);
            _mm512_mask_storeu_epi32(p, mask, _mm512_add_epi32(prev, v));
        }
    }
}

#[cfg(not(target_arch = "x86_64"))]
pub unsafe fn igemm_vnni_tiled(
    _m: usize,
    _apack: &[i32],
    _bp: &PackedB,
    _cbase: *mut i32,
    _j0: usize,
    _j1: usize,
) {
    unreachable!("vnni_available() is false on this arch")
}

#[cfg(not(target_arch = "x86_64"))]
pub unsafe fn igemm_vnni_tiled_rows(
    _m: usize,
    _apack: &[i32],
    _bp: &PackedB,
    _cbase: *mut i32,
    _i0: usize,
    _i1: usize,
) {
    unreachable!("vnni_available() is false on this arch")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::igemm_naive;
    use crate::util::prop::{check, gen};

    #[test]
    fn pack_a_quad_major() {
        // k = 6: one full quad + a padded tail quad, m = 2
        let a: Vec<i8> = vec![1, -2, 3, -4, 5, -6, 10, 20, 30, 40, 50, 60];
        let mut out = Vec::new();
        pack_a(&a, 2, 6, &mut out);
        assert_eq!(out.len(), 2 * 2);
        // quad-major: [q0r0, q0r1, q1r0, q1r1]
        assert_eq!(out[0], i32::from_le_bytes([1, -2i8 as u8, 3, -4i8 as u8]));
        assert_eq!(out[1], i32::from_le_bytes([10, 20, 30, 40]));
        assert_eq!(out[2], i32::from_le_bytes([5, -6i8 as u8, 0, 0]));
        assert_eq!(out[3], i32::from_le_bytes([50, 60, 0, 0]));
    }

    #[test]
    fn vnni_matches_naive_prop() {
        if !vnni_available() {
            eprintln!("skipping: no AVX-512 VNNI");
            return;
        }
        check("vnni==naive", 77, 40, |rng, _| {
            let (m, k, n) = gen::gemm_dims(rng, 70);
            let a: Vec<i8> = (0..m * k).map(|_| rng.next_u64() as i8).collect();
            let b: Vec<u8> = (0..k * n).map(|_| rng.next_u64() as u8).collect();
            let bp = PackedB::pack(&b, k, n);
            let mut c1 = vec![0i32; m * n];
            unsafe { igemm_vnni(m, k, &a, &bp, &mut c1) };
            let mut c2 = vec![0i32; m * n];
            igemm_naive(m, k, n, &a, &b, &mut c2);
            if c1 != c2 {
                return Err(format!("mismatch at ({m},{k},{n})"));
            }
            Ok(())
        });
    }

    #[test]
    fn vnni_tiled_matches_naive_prop() {
        if !vnni_available() {
            eprintln!("skipping: no AVX-512 VNNI");
            return;
        }
        check("vnni-tiled==naive", 0x71ED, 48, |rng, case| {
            let (dm, dk, dn) = gen::gemm_dims(rng, 70);
            let (mut m, mut k, mut n) = (dm, dk, dn);
            match case % 4 {
                0 => m = 1,
                1 => n = (n / 32) * 32 + 1 + (n % 31),
                2 => k = (k / 4) * 4 + 1 + (k % 3),
                _ => {}
            }
            let a: Vec<i8> = (0..m * k).map(|_| rng.next_u64() as i8).collect();
            let b: Vec<u8> = (0..k * n).map(|_| rng.next_u64() as u8).collect();
            let bp = PackedB::pack(&b, k, n);
            let mut ap = Vec::new();
            pack_a(&a, m, k, &mut ap);
            let mut c = vec![0i32; m * n];
            unsafe { igemm_vnni_tiled(m, &ap, &bp, c.as_mut_ptr(), 0, n) };
            let mut want = vec![0i32; m * n];
            igemm_naive(m, k, n, &a, &b, &mut want);
            if c != want {
                return Err(format!("mismatch at ({m},{k},{n})"));
            }
            Ok(())
        });
    }

    #[test]
    fn vnni_extreme_values() {
        if !vnni_available() {
            return;
        }
        let (m, k, n) = (2, 9, 17); // ragged everything
        let a = vec![-128i8; m * k];
        let b = vec![255u8; k * n];
        let bp = PackedB::pack(&b, k, n);
        let mut c = vec![0i32; m * n];
        unsafe { igemm_vnni(m, k, &a, &bp, &mut c) };
        assert!(c.iter().all(|&x| x == -128 * 255 * k as i32));

        let mut ap = Vec::new();
        pack_a(&a, m, k, &mut ap);
        let mut ct = vec![0i32; m * n];
        unsafe { igemm_vnni_tiled(m, &ap, &bp, ct.as_mut_ptr(), 0, n) };
        assert_eq!(c, ct);
    }

    #[test]
    fn vnni_accumulates_into_c() {
        if !vnni_available() {
            return;
        }
        let a = vec![1i8; 4];
        let b = vec![1u8; 4];
        let bp = PackedB::pack(&b, 4, 1);
        let mut c = vec![100i32];
        unsafe { igemm_vnni(1, 4, &a, &bp, &mut c) };
        assert_eq!(c[0], 104);
    }

    #[test]
    fn vnni_tiled_rows_match_full_run() {
        if !vnni_available() {
            return;
        }
        // row-striped execution (uneven split, MR-misaligned boundary)
        // must be bit-identical to one full-range call
        let (m, k, n) = (29, 37, 21);
        let a: Vec<i8> = (0..m * k).map(|i| (i as i32 * 11 % 251 - 125) as i8).collect();
        let b: Vec<u8> = (0..k * n).map(|i| (i * 23 % 256) as u8).collect();
        let bp = PackedB::pack(&b, k, n);
        let mut ap = Vec::new();
        pack_a(&a, m, k, &mut ap);
        let mut want = vec![0i32; m * n];
        unsafe { igemm_vnni_tiled(m, &ap, &bp, want.as_mut_ptr(), 0, n) };
        let mut c = vec![0i32; m * n];
        for (i0, i1) in [(0usize, 5usize), (5, 16), (16, 29)] {
            unsafe { igemm_vnni_tiled_rows(m, &ap, &bp, c.as_mut_ptr(), i0, i1) };
        }
        assert_eq!(c, want);
    }

    #[test]
    fn vnni_tiled_deep_k_multiple_blocks() {
        if !vnni_available() {
            return;
        }
        // k > 4*KC_QUADS forces the load+add+store accumulate path
        let (m, k, n) = (7, 4 * crate::gemm::KC_QUADS + 5, 33);
        let a: Vec<i8> = (0..m * k).map(|i| (i as i32 % 251 - 125) as i8).collect();
        let b: Vec<u8> = (0..k * n).map(|i| (i * 17 % 256) as u8).collect();
        let bp = PackedB::pack(&b, k, n);
        let mut ap = Vec::new();
        pack_a(&a, m, k, &mut ap);
        let mut c = vec![0i32; m * n];
        unsafe { igemm_vnni_tiled(m, &ap, &bp, c.as_mut_ptr(), 0, n) };
        let mut want = vec![0i32; m * n];
        igemm_naive(m, k, n, &a, &b, &mut want);
        assert_eq!(c, want);
    }
}
