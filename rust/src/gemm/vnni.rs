//! The real thing: AVX-512 VNNI `vpdpbusd` GEMM micro-kernel.
//!
//! `vpdpbusd dst, src1, src2` computes, per i32 lane,
//! `dst += sum_{q=0..4} src1.u8[4i+q] * src2.s8[4i+q]` — 64 byte-MACs
//! per instruction.  This is the exact instruction the paper's MKL
//! kernel leans on (§2, §5.2).  Mapping to our `A_s8 [m,k] x B_u8 [k,n]`:
//! the *unsigned* operand is B and the *signed* operand is A, so each
//! instruction takes 16 j-lanes of B quads against a broadcast A quad.
//!
//! B must be repacked so that each lane's 4 consecutive k-bytes are
//! contiguous: `bp[p/4][j][q] = b[(p+q)*n + j]` (the "k/4-packed"
//! layout every VNNI GEMM uses).  Packing costs one pass over B and is
//! amortized over all m rows — and the engine pre-packs its weight
//! operands once at construction.
//!
//! Feature-gated at runtime: [`vnni_available`] falls back to the
//! portable quad-MAC kernel on machines without AVX-512 VNNI.

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Lanes per vpdpbusd (16 i32 lanes in a zmm).
pub const VNNI_LANES: usize = 16;

/// Runtime check for AVX-512 VNNI (+ the AVX-512F/BW baseline we use).
pub fn vnni_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx512vnni")
            && std::arch::is_x86_feature_detected!("avx512f")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Packed-B buffer for the VNNI kernel.
///
/// Geometry: `kp = ceil(k/4)` quads, `np = ceil(n/16)*16` padded lanes;
/// layout `[kp][np][4]` bytes with zero padding (zero u8 bytes contribute
/// 0 to every product, so padding is neutral *before* the zero-point
/// correction, which uses the true k).
#[derive(Default)]
pub struct PackedB {
    pub data: Vec<u8>,
    pub k: usize,
    pub n: usize,
    pub kp: usize,
    pub np: usize,
}

impl PackedB {
    /// Pack row-major `b [k, n]` into VNNI layout.
    pub fn pack(b: &[u8], k: usize, n: usize) -> PackedB {
        assert_eq!(b.len(), k * n);
        let kp = k.div_ceil(4);
        let np = n.div_ceil(VNNI_LANES) * VNNI_LANES;
        let mut data = vec![0u8; kp * np * 4];
        for p in 0..k {
            let quad = p / 4;
            let q = p % 4;
            let brow = &b[p * n..(p + 1) * n];
            let dst = &mut data[quad * np * 4..(quad + 1) * np * 4];
            for j in 0..n {
                dst[j * 4 + q] = brow[j];
            }
        }
        PackedB { data, k, n, kp, np }
    }

    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// `c[m,n] += a[m,k] x B` via vpdpbusd. Caller must zero `c` first and
/// have checked [`vnni_available`].
///
/// # Safety
/// Requires AVX-512F + AVX-512VNNI (checked by the caller).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
pub unsafe fn igemm_vnni(m: usize, k: usize, a: &[i8], bp: &PackedB, c: &mut [i32]) {
    let n = bp.n;
    let np = bp.np;
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    debug_assert_eq!(bp.k, k);

    // a row padded to quads on the stack when k % 4 != 0
    let kq = k / 4;
    let k_tail = k % 4;

    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let mut jt = 0;
        while jt < n {
            let lanes = VNNI_LANES.min(n - jt);
            let mut acc = _mm512_setzero_si512();
            // full quads
            for quad in 0..kq {
                // broadcast 4 signed A bytes to every lane
                let a_quad = i32::from_le_bytes([
                    arow[quad * 4] as u8,
                    arow[quad * 4 + 1] as u8,
                    arow[quad * 4 + 2] as u8,
                    arow[quad * 4 + 3] as u8,
                ]);
                let av = _mm512_set1_epi32(a_quad);
                let bptr = bp.data.as_ptr().add(quad * np * 4 + jt * 4) as *const i32;
                let bv = _mm512_loadu_si512(bptr as *const _);
                // unsigned operand = B, signed operand = A
                acc = _mm512_dpbusd_epi32(acc, bv, av);
            }
            // ragged k tail (0..3 remaining rows): pad A quad with zeros
            if k_tail != 0 {
                let mut quad_bytes = [0u8; 4];
                for (q, qb) in quad_bytes.iter_mut().enumerate().take(k_tail) {
                    *qb = arow[kq * 4 + q] as u8;
                }
                let av = _mm512_set1_epi32(i32::from_le_bytes(quad_bytes));
                let bptr = bp.data.as_ptr().add(kq * np * 4 + jt * 4) as *const i32;
                let bv = _mm512_loadu_si512(bptr as *const _);
                acc = _mm512_dpbusd_epi32(acc, bv, av);
            }
            // store (masked on the ragged right edge)
            let cptr = c.as_mut_ptr().add(i * n + jt);
            if lanes == VNNI_LANES {
                let prev = _mm512_loadu_si512(cptr as *const _);
                _mm512_storeu_si512(cptr as *mut _, _mm512_add_epi32(prev, acc));
            } else {
                let mask: u16 = (1u16 << lanes) - 1;
                let prev = _mm512_maskz_loadu_epi32(mask, cptr);
                _mm512_mask_storeu_epi32(cptr, mask, _mm512_add_epi32(prev, acc));
            }
            jt += VNNI_LANES;
        }
    }
}

#[cfg(not(target_arch = "x86_64"))]
pub unsafe fn igemm_vnni(_m: usize, _k: usize, _a: &[i8], _bp: &PackedB, _c: &mut [i32]) {
    unreachable!("vnni_available() is false on this arch")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::igemm_naive;
    use crate::util::prop::{check, gen};

    #[test]
    fn pack_layout_roundtrip() {
        let k = 6;
        let n = 3;
        let b: Vec<u8> = (0..k * n).map(|x| x as u8).collect();
        let bp = PackedB::pack(&b, k, n);
        assert_eq!(bp.kp, 2);
        assert_eq!(bp.np, 16);
        // element b[p, j] must live at data[(p/4)*np*4 + j*4 + p%4]
        for p in 0..k {
            for j in 0..n {
                assert_eq!(
                    bp.data[(p / 4) * bp.np * 4 + j * 4 + p % 4],
                    b[p * n + j],
                    "(p={p}, j={j})"
                );
            }
        }
    }

    #[test]
    fn vnni_matches_naive_prop() {
        if !vnni_available() {
            eprintln!("skipping: no AVX-512 VNNI");
            return;
        }
        check("vnni==naive", 77, 40, |rng, _| {
            let (m, k, n) = gen::gemm_dims(rng, 70);
            let a: Vec<i8> = (0..m * k).map(|_| rng.next_u64() as i8).collect();
            let b: Vec<u8> = (0..k * n).map(|_| rng.next_u64() as u8).collect();
            let bp = PackedB::pack(&b, k, n);
            let mut c1 = vec![0i32; m * n];
            unsafe { igemm_vnni(m, k, &a, &bp, &mut c1) };
            let mut c2 = vec![0i32; m * n];
            igemm_naive(m, k, n, &a, &b, &mut c2);
            if c1 != c2 {
                return Err(format!("mismatch at ({m},{k},{n})"));
            }
            Ok(())
        });
    }

    #[test]
    fn vnni_extreme_values() {
        if !vnni_available() {
            return;
        }
        let (m, k, n) = (2, 9, 17); // ragged everything
        let a = vec![-128i8; m * k];
        let b = vec![255u8; k * n];
        let bp = PackedB::pack(&b, k, n);
        let mut c = vec![0i32; m * n];
        unsafe { igemm_vnni(m, k, &a, &bp, &mut c) };
        assert!(c.iter().all(|&x| x == -128 * 255 * k as i32));
    }

    #[test]
    fn vnni_accumulates_into_c() {
        if !vnni_available() {
            return;
        }
        let a = vec![1i8; 4];
        let b = vec![1u8; 4];
        let bp = PackedB::pack(&b, 4, 1);
        let mut c = vec![100i32];
        unsafe { igemm_vnni(1, 4, &a, &bp, &mut c) };
        assert_eq!(c[0], 104);
    }
}
