//! Runtime kernel dispatch: the ISA ladder, GEMM worker-thread sizing,
//! and the column-stripe partitioner shared by the int8 and f32 GEMMs.
//!
//! The paper's kernel (§5.2, MKL `s8 x u8 -> s32`) picks its code path
//! by CPU capability and matrix shape; this module is our equivalent of
//! that dispatch table:
//!
//! * [`IsaLevel`] — the capability ladder
//!   `Scalar < Avx2 < Avx512Vnni`.  [`isa_level`] caches the detected
//!   level once per process, capped by the `QUANTNMT_ISA` environment
//!   override (`scalar` / `avx2` / `vnni`, for CI and A/B runs) and the
//!   legacy `QUANTNMT_NO_VNNI` switch.  Overrides cap **Auto** dispatch
//!   only; an explicit `KernelChoice` still runs its kernel.
//! * [`gemm_threads`] / [`set_gemm_threads`] — process-wide worker
//!   count for the parallel macro-loop, settable from
//!   `ServiceConfig`/`ServerConfig` (CLI `--gemm-threads`) or the
//!   `QUANTNMT_GEMM_THREADS` environment variable.
//! * [`run_cols`] — partitions the output columns `[0, n)` into
//!   [`STRIPE_ALIGN`]-aligned stripes and runs one worker per stripe on
//!   a crossbeam scoped pool.
//!
//! **Determinism invariant**: stripes write *disjoint* column ranges of
//! C and every kernel keeps the per-element k-summation order fixed, so
//! results are bit-identical for every thread count (integer kernels
//! are exact anyway; the f32 kernel's per-element order never depends
//! on the column partition).  `tests` in `gemm::igemm` assert this
//! across the kernel x thread-count cross product.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// The instruction-set ladder the int8 GEMM dispatches over.
///
/// Ordering is meaningful: `Scalar < Avx2 < Avx512Vnni`, so an
/// environment override can *cap* the detected level with `min`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum IsaLevel {
    /// portable blocked quad-MAC kernel (autovectorized by rustc)
    Scalar,
    /// 256-bit `pmaddwd` even/odd-split kernel (exact, non-saturating)
    Avx2,
    /// 512-bit `vpdpbusd` register-tiled macro-kernel
    Avx512Vnni,
}

impl IsaLevel {
    pub fn as_str(self) -> &'static str {
        match self {
            IsaLevel::Scalar => "scalar",
            IsaLevel::Avx2 => "avx2",
            IsaLevel::Avx512Vnni => "avx512vnni",
        }
    }

    /// Whether this tier consumes the k/4-packed B panel (the scalar
    /// tier can read one, but never *requires* packing).
    pub fn packs_b(self) -> bool {
        self != IsaLevel::Scalar
    }
}

/// Parse a `QUANTNMT_ISA` value (`scalar`/`portable`, `avx2`,
/// `vnni`/`avx512`/`avx512vnni`); `None` for anything else.
pub fn parse_isa(s: &str) -> Option<IsaLevel> {
    match s.trim().to_ascii_lowercase().as_str() {
        "scalar" | "portable" => Some(IsaLevel::Scalar),
        "avx2" => Some(IsaLevel::Avx2),
        "vnni" | "avx512" | "avx512vnni" => Some(IsaLevel::Avx512Vnni),
        _ => None,
    }
}

/// Runtime AVX2 check (the 256-bit tier's only requirement).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Hardware capability, uncached and ignoring every override.
pub fn detect_isa() -> IsaLevel {
    if super::vnni::vnni_available() {
        IsaLevel::Avx512Vnni
    } else if avx2_available() {
        IsaLevel::Avx2
    } else {
        IsaLevel::Scalar
    }
}

/// Cached dispatch level: [`detect_isa`] capped by `QUANTNMT_ISA` and
/// the legacy `QUANTNMT_NO_VNNI` switch.  Requesting a level the
/// hardware lacks caps at the hardware (asking for `vnni` on an
/// AVX2-only machine runs AVX2, not an illegal instruction).
pub fn isa_level() -> IsaLevel {
    static LEVEL: OnceLock<IsaLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        let mut level = detect_isa();
        if let Ok(v) = std::env::var("QUANTNMT_ISA") {
            match parse_isa(&v) {
                Some(req) => level = level.min(req),
                None => eprintln!(
                    "QUANTNMT_ISA='{v}' not recognized (want scalar|avx2|vnni); \
                     using detected level {}",
                    level.as_str()
                ),
            }
        }
        if std::env::var("QUANTNMT_NO_VNNI").is_ok() {
            level = level.min(IsaLevel::Avx2);
        }
        level
    })
}

/// Upper bound on the auto-sized worker count (more threads than this
/// never helped the bench shapes and fights the service's stream-level
/// parallelism for cores).
pub const DEFAULT_MAX_THREADS: usize = 4;

static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide GEMM worker count (`0` resets to the
/// environment/auto default).  Called by `Service::run` / `serve` from
/// their configs before any engine work starts.
pub fn set_gemm_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// The process-wide GEMM worker count: the [`set_gemm_threads`]
/// override if set, else `QUANTNMT_GEMM_THREADS`, else
/// `min(available_parallelism, DEFAULT_MAX_THREADS)`.
pub fn gemm_threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::env::var("QUANTNMT_GEMM_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|p| p.get())
                    .unwrap_or(1)
                    .min(DEFAULT_MAX_THREADS)
            })
    })
}

/// Minimum MAC count (`2*m*k*n` flops) before auto threading engages.
/// Below this the scoped-thread spawn costs more than the GEMM: an
/// m == 1 decode step (`2*1*512*512 ≈ 0.5M`) never pays thread
/// overhead, while every batch>=8 prefill shape clears the bar.
pub const PAR_FLOPS_MIN: usize = 1 << 22;

/// Column-stripe alignment: a full 2-vector column group of the widest
/// kernel (32 i32 lanes), so no stripe boundary ever splits a store.
pub const STRIPE_ALIGN: usize = 32;

/// On-the-fly pack crossover for Auto dispatch: packing B costs one
/// O(k*n) pass, amortized over the m x n output tile.  Measured in
/// `benches/gemm.rs` (crossover sweep; see EXPERIMENTS.md): packing
/// pays once the output tile has at least [`AUTO_PACK_MIN_ROWS`] rows
/// *and* [`AUTO_PACK_MIN_MN`] elements.
pub const AUTO_PACK_MIN_ROWS: usize = 2;
/// See [`AUTO_PACK_MIN_ROWS`].
pub const AUTO_PACK_MIN_MN: usize = 512;

/// Shape-aware Auto-dispatch predicate: is packing B on the fly worth
/// it for an `m x n` output tile?  (Prepacked panels skip this — their
/// pack cost was paid at plan-compile time.)
pub fn pack_pays(m: usize, n: usize) -> bool {
    m >= AUTO_PACK_MIN_ROWS && m * n >= AUTO_PACK_MIN_MN
}

/// Resolve the worker count for one GEMM call.  `requested == 0` means
/// auto: the global [`gemm_threads`] setting, gated by
/// [`PAR_FLOPS_MIN`] so small/decode GEMMs stay single-threaded.  An
/// explicit `requested` (tests, benches) is honored regardless of
/// shape, clamped to the number of stripes.
pub(crate) fn effective_threads(requested: usize, m: usize, k: usize, n: usize) -> usize {
    let t = if requested == 0 {
        let auto = gemm_threads();
        let macs = 2 * m.saturating_mul(k).saturating_mul(n);
        if auto <= 1 || macs < PAR_FLOPS_MIN {
            1
        } else {
            auto
        }
    } else {
        requested
    };
    t.clamp(1, n.div_ceil(STRIPE_ALIGN).max(1))
}

/// Partition `[0, n)` into up to `stripes` column ranges, each a
/// multiple of [`STRIPE_ALIGN`] wide except the last.
pub(crate) fn stripe_ranges(n: usize, stripes: usize) -> Vec<(usize, usize)> {
    let stripes = stripes.max(1);
    let width = n.div_ceil(stripes).div_ceil(STRIPE_ALIGN) * STRIPE_ALIGN;
    let mut out = Vec::new();
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + width).min(n);
        out.push((j0, j1));
        j0 = j1;
    }
    out
}

/// Run `f(j0, j1)` over the column stripes of `[0, n)`, one scoped
/// worker per stripe (the first stripe runs on the calling thread).
///
/// Callers pass a closure writing **disjoint** column ranges of C via a
/// [`SendPtr`]; with the per-element summation order fixed inside each
/// kernel, the output is bit-identical for every `threads` value.
pub(crate) fn run_cols<F>(threads: usize, n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if threads <= 1 {
        f(0, n);
        return;
    }
    let ranges = stripe_ranges(n, threads);
    if ranges.len() <= 1 {
        f(0, n);
        return;
    }
    crossbeam_utils::thread::scope(|scope| {
        for &(j0, j1) in ranges.iter().skip(1) {
            let f = &f;
            scope.spawn(move |_| f(j0, j1));
        }
        f(ranges[0].0, ranges[0].1);
    })
    .expect("gemm worker thread panicked");
}

/// Raw mutable base pointer that may cross scoped-thread boundaries.
///
/// Safety contract: every worker receiving a copy writes a disjoint
/// region (the [`run_cols`] column stripes), and the pointee outlives
/// the scope (guaranteed by `crossbeam_utils::thread::scope` joining
/// before the caller's borrow ends).
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(pub *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isa_ladder_orders() {
        assert!(IsaLevel::Scalar < IsaLevel::Avx2);
        assert!(IsaLevel::Avx2 < IsaLevel::Avx512Vnni);
        // env override caps, never raises
        assert_eq!(IsaLevel::Avx512Vnni.min(IsaLevel::Avx2), IsaLevel::Avx2);
    }

    #[test]
    fn parse_isa_values() {
        assert_eq!(parse_isa("scalar"), Some(IsaLevel::Scalar));
        assert_eq!(parse_isa("portable"), Some(IsaLevel::Scalar));
        assert_eq!(parse_isa(" AVX2 "), Some(IsaLevel::Avx2));
        assert_eq!(parse_isa("vnni"), Some(IsaLevel::Avx512Vnni));
        assert_eq!(parse_isa("avx512vnni"), Some(IsaLevel::Avx512Vnni));
        assert_eq!(parse_isa("mmx"), None);
    }

    #[test]
    fn isa_level_capped_by_hardware() {
        // whatever the env says, the cached level can't exceed hardware
        assert!(isa_level() <= detect_isa());
    }

    #[test]
    fn stripes_align_and_cover() {
        for (n, t) in [(1usize, 4usize), (31, 2), (32, 2), (97, 3), (512, 4), (513, 7)] {
            let r = stripe_ranges(n, t);
            assert!(r.len() <= t.max(1));
            assert_eq!(r.first().unwrap().0, 0);
            assert_eq!(r.last().unwrap().1, n);
            for w in r.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            for &(j0, j1) in &r[..r.len() - 1] {
                assert_eq!((j1 - j0) % STRIPE_ALIGN, 0, "aligned stripe ({n},{t})");
            }
        }
        assert!(stripe_ranges(0, 3).is_empty());
    }

    #[test]
    fn effective_threads_gates_small_shapes() {
        // auto: decode-sized GEMM never threads
        assert_eq!(effective_threads(0, 1, 512, 512), 1);
        // explicit request is honored but clamped to stripe count
        assert_eq!(effective_threads(4, 1, 8, 33), 2);
        assert_eq!(effective_threads(2, 1, 8, 8), 1);
    }

    #[test]
    fn pack_crossover_shape_aware() {
        assert!(!pack_pays(1, 4096), "m == 1 never repacks on the fly");
        assert!(!pack_pays(2, 128), "tiny tiles stay portable");
        assert!(pack_pays(2, 256));
        assert!(pack_pays(64, 64));
    }

    #[test]
    fn run_cols_covers_all_columns() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let n = 100;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        run_cols(4, n, |j0, j1| {
            for h in &hits[j0..j1] {
                h.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }
}
