//! Runtime kernel dispatch: the ISA ladder, GEMM worker-thread sizing,
//! and the stripe partitioner shared by the int8 and f32 GEMMs.
//!
//! The paper's kernel (§5.2, MKL `s8 x u8 -> s32`) picks its code path
//! by CPU capability and matrix shape; this module is our equivalent of
//! that dispatch table:
//!
//! * [`IsaLevel`] — the capability ladder
//!   `Scalar < Avx2 < Avx512Vnni`.  [`isa_level`] caches the detected
//!   level once per process, capped by the `QUANTNMT_ISA` environment
//!   override (`scalar` / `avx2` / `vnni`, for CI and A/B runs) and the
//!   legacy `QUANTNMT_NO_VNNI` switch.  Overrides cap **Auto** dispatch
//!   only; an explicit `KernelChoice` still runs its kernel.
//! * [`gemm_threads`] / [`set_gemm_threads`] — process-wide worker
//!   count for the parallel macro-loop, settable from
//!   `ServiceConfig`/`ServerConfig` (CLI `--gemm-threads`) or the
//!   `QUANTNMT_GEMM_THREADS` environment variable.
//! * [`run_cols`] / [`run_rows`] — partition the output columns (or,
//!   for tall-skinny shapes, the output rows) into aligned stripes and
//!   fan them out on the persistent worker pool ([`super::pool`]); when
//!   the pool is disabled (`--gemm-pool off`) they fall back to the old
//!   per-call crossbeam scoped spawn.
//! * [`plan_partition`] — the shape-aware axis + worker-count decision,
//!   gated by the dispatch-cost crossover ([`PAR_FLOPS_MIN_POOLED`] on
//!   the pooled path, the much higher [`PAR_FLOPS_MIN`] when each call
//!   pays a spawn).
//!
//! **Determinism invariant**: stripes write *disjoint* column (or row)
//! ranges of C and every kernel keeps the per-element k-summation order
//! fixed, so results are bit-identical for every thread count, stripe
//! axis, and dispatch path (integer kernels are exact anyway; the f32
//! kernel's per-element order never depends on the output partition).
//! `tests` in `gemm::igemm` and `tests/pool_parity.rs` assert this
//! across the kernel x packing x thread-count x dispatch-path grid.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::OnceLock;

/// The instruction-set ladder the int8 GEMM dispatches over.
///
/// Ordering is meaningful: `Scalar < Avx2 < Avx512Vnni`, so an
/// environment override can *cap* the detected level with `min`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum IsaLevel {
    /// portable blocked quad-MAC kernel (autovectorized by rustc)
    Scalar,
    /// 256-bit `pmaddwd` even/odd-split kernel (exact, non-saturating)
    Avx2,
    /// 512-bit `vpdpbusd` register-tiled macro-kernel
    Avx512Vnni,
}

impl IsaLevel {
    pub fn as_str(self) -> &'static str {
        match self {
            IsaLevel::Scalar => "scalar",
            IsaLevel::Avx2 => "avx2",
            IsaLevel::Avx512Vnni => "avx512vnni",
        }
    }

    /// Whether this tier consumes the k/4-packed B panel (the scalar
    /// tier can read one, but never *requires* packing).
    pub fn packs_b(self) -> bool {
        self != IsaLevel::Scalar
    }
}

/// Parse a `QUANTNMT_ISA` value (`scalar`/`portable`, `avx2`,
/// `vnni`/`avx512`/`avx512vnni`); `None` for anything else.
pub fn parse_isa(s: &str) -> Option<IsaLevel> {
    match s.trim().to_ascii_lowercase().as_str() {
        "scalar" | "portable" => Some(IsaLevel::Scalar),
        "avx2" => Some(IsaLevel::Avx2),
        "vnni" | "avx512" | "avx512vnni" => Some(IsaLevel::Avx512Vnni),
        _ => None,
    }
}

/// Runtime AVX2 check (the 256-bit tier's only requirement).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Hardware capability, uncached and ignoring every override.
pub fn detect_isa() -> IsaLevel {
    if super::vnni::vnni_available() {
        IsaLevel::Avx512Vnni
    } else if avx2_available() {
        IsaLevel::Avx2
    } else {
        IsaLevel::Scalar
    }
}

/// Cached dispatch level: [`detect_isa`] capped by `QUANTNMT_ISA` and
/// the legacy `QUANTNMT_NO_VNNI` switch.  Requesting a level the
/// hardware lacks caps at the hardware (asking for `vnni` on an
/// AVX2-only machine runs AVX2, not an illegal instruction).
pub fn isa_level() -> IsaLevel {
    static LEVEL: OnceLock<IsaLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        let mut level = detect_isa();
        if let Ok(v) = std::env::var("QUANTNMT_ISA") {
            match parse_isa(&v) {
                Some(req) => level = level.min(req),
                None => eprintln!(
                    "QUANTNMT_ISA='{v}' not recognized (want scalar|avx2|vnni); \
                     using detected level {}",
                    level.as_str()
                ),
            }
        }
        if std::env::var("QUANTNMT_NO_VNNI").is_ok() {
            level = level.min(IsaLevel::Avx2);
        }
        level
    })
}

/// Upper bound on the auto-sized worker count (more threads than this
/// never helped the bench shapes and fights the service's stream-level
/// parallelism for cores).
pub const DEFAULT_MAX_THREADS: usize = 4;

static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide GEMM worker count (`0` resets to the
/// environment/auto default).  Called by `Service::run` / `serve` from
/// their configs before any engine work starts.
pub fn set_gemm_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// The process-wide GEMM worker count: the [`set_gemm_threads`]
/// override if set, else `QUANTNMT_GEMM_THREADS`, else
/// `min(available_parallelism, DEFAULT_MAX_THREADS)`.
pub fn gemm_threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::env::var("QUANTNMT_GEMM_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|p| p.get())
                    .unwrap_or(1)
                    .min(DEFAULT_MAX_THREADS)
            })
    })
}

/// Minimum flop count (`2*m*k*n`) before auto threading engages on the
/// **scoped-spawn** fallback path (`--gemm-pool off`).  Below this the
/// per-call thread spawn costs more than the GEMM: an m == 1 decode
/// step (`2*1*512*512 ≈ 0.5M`) never pays spawn overhead, while every
/// batch>=8 prefill shape clears the bar.
pub const PAR_FLOPS_MIN: usize = 1 << 22;

/// Minimum flop count before auto threading engages on the **pooled**
/// path.  With spawn/join amortized by the persistent worker pool,
/// dispatch costs a few atomics + an unpark (~1 µs worst case vs
/// ~40 µs for a scoped spawn+join; `benches/gemm.rs` `dispatch` rows,
/// EXPERIMENTS.md "Dispatch overhead"), so the crossover drops ~32x
/// and decode-shape GEMMs (m = active slots, the per-token logits
/// dense m=slots x n=vocab above all) actually go parallel.  Derived
/// from the `pool-crossover` sweep in `benches/gemm.rs`; override with
/// `QUANTNMT_GEMM_PAR_MIN` when re-tuning for different hardware.
pub const PAR_FLOPS_MIN_POOLED: usize = 1 << 17;

/// The active auto-threading crossover: the `QUANTNMT_GEMM_PAR_MIN`
/// override if set, else pooled/scoped per the current dispatch path.
fn par_flops_min() -> usize {
    static ENV: OnceLock<Option<usize>> = OnceLock::new();
    let env = *ENV.get_or_init(|| {
        std::env::var("QUANTNMT_GEMM_PAR_MIN").ok().and_then(|s| s.parse::<usize>().ok())
    });
    env.unwrap_or(if super::pool::enabled() { PAR_FLOPS_MIN_POOLED } else { PAR_FLOPS_MIN })
}

/// Column-stripe alignment: a full 2-vector column group of the widest
/// kernel (32 i32 lanes), so no stripe boundary ever splits a store.
pub const STRIPE_ALIGN: usize = 32;

/// Row-stripe alignment: the f32 and AVX2 micro-kernels walk rows in
/// groups of 4; aligning stripe boundaries keeps full groups together
/// (row grouping never changes any element's summation order, so this
/// is a throughput choice, not a correctness one).
pub const ROW_STRIPE_ALIGN: usize = 4;

/// Minimum rows per row stripe before the row axis is worth choosing —
/// below this the per-stripe A-panel/loop overhead beats the win.
pub const ROW_STRIPE_MIN: usize = 8;

/// On-the-fly pack crossover for Auto dispatch: packing B costs one
/// O(k*n) pass, amortized over the m x n output tile.  Measured in
/// `benches/gemm.rs` (crossover sweep; see EXPERIMENTS.md): packing
/// pays once the output tile has at least [`AUTO_PACK_MIN_ROWS`] rows
/// *and* [`AUTO_PACK_MIN_MN`] elements.
pub const AUTO_PACK_MIN_ROWS: usize = 2;
/// See [`AUTO_PACK_MIN_ROWS`].
pub const AUTO_PACK_MIN_MN: usize = 512;

/// Shape-aware Auto-dispatch predicate: is packing B on the fly worth
/// it for an `m x n` output tile?  (Prepacked panels skip this — their
/// pack cost was paid at plan-compile time.)
pub fn pack_pays(m: usize, n: usize) -> bool {
    m >= AUTO_PACK_MIN_ROWS && m * n >= AUTO_PACK_MIN_MN
}

/// The dispatch-cost gate without any stripe clamp: `requested == 0`
/// means auto — the global [`gemm_threads`] setting, gated by the
/// active crossover ([`par_flops_min`]) so GEMMs too small to pay
/// dispatch stay single-threaded.  An explicit `requested` (tests,
/// benches) is honored regardless of shape.
fn gated_threads(requested: usize, m: usize, k: usize, n: usize) -> usize {
    if requested != 0 {
        return requested;
    }
    let auto = gemm_threads();
    let flops = 2 * m.saturating_mul(k).saturating_mul(n);
    if auto <= 1 || flops < par_flops_min() {
        1
    } else {
        auto
    }
}

/// Resolve the worker count for one column-striped GEMM call:
/// [`gated_threads`] clamped to the number of column stripes.
pub(crate) fn effective_threads(requested: usize, m: usize, k: usize, n: usize) -> usize {
    gated_threads(requested, m, k, n).clamp(1, n.div_ceil(STRIPE_ALIGN).max(1))
}

/// The stripe axis + worker count chosen for one `m x n` output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Partition {
    /// fan out over column stripes (`run_cols`); 1 means run inline
    Cols(usize),
    /// fan out over row stripes (`run_rows`) — tall-skinny shapes only
    Rows(usize),
}

/// Shape-aware parallelism plan for one GEMM call.  Columns are the
/// default axis (SIMD stores never split, B panel locality).  The row
/// axis is chosen only for tall-skinny outputs (m ≫ n) where `[0, n)`
/// has too few [`STRIPE_ALIGN`]-wide stripes to feed the requested
/// workers — e.g. a prefill attention-score block, or m=256 x n=24.
/// Both axes partition *disjoint output ranges* and never touch any
/// element's k-summation order, so the choice is invisible in the bits.
pub(crate) fn plan_partition(requested: usize, m: usize, k: usize, n: usize) -> Partition {
    let want = gated_threads(requested, m, k, n);
    if want <= 1 {
        return Partition::Cols(1);
    }
    let col_stripes = n.div_ceil(STRIPE_ALIGN).max(1);
    if col_stripes < want && m > n && m >= want * ROW_STRIPE_MIN {
        Partition::Rows(want.min(m.div_ceil(ROW_STRIPE_MIN)))
    } else {
        Partition::Cols(effective_threads(requested, m, k, n))
    }
}

/// Stripe width for partitioning `[0, len)` into up to `stripes`
/// ranges, each a multiple of `align` wide (except the last).  Shared
/// by [`stripe_ranges`], the scoped fallback and the pool so every
/// dispatch path produces the identical partition.
pub(crate) fn stripe_width(len: usize, stripes: usize, align: usize) -> usize {
    len.div_ceil(stripes.max(1)).div_ceil(align).max(1) * align
}

/// Partition `[0, len)` into up to `stripes` ranges of `align`-multiple
/// width (see [`stripe_width`]).
pub(crate) fn stripe_ranges_with(len: usize, stripes: usize, align: usize) -> Vec<(usize, usize)> {
    let width = stripe_width(len, stripes, align);
    let mut out = Vec::new();
    let mut j0 = 0;
    while j0 < len {
        let j1 = (j0 + width).min(len);
        out.push((j0, j1));
        j0 = j1;
    }
    out
}

/// Partition `[0, n)` into up to `stripes` column ranges, each a
/// multiple of [`STRIPE_ALIGN`] wide except the last.
pub(crate) fn stripe_ranges(n: usize, stripes: usize) -> Vec<(usize, usize)> {
    stripe_ranges_with(n, stripes, STRIPE_ALIGN)
}

static OVERSUBSCRIBE_WARNED: AtomicBool = AtomicBool::new(false);

/// Satellite of the pool design: an explicit thread request larger than
/// the pool (e.g. `QUANTNMT_GEMM_THREADS=8` against a 4-lane pool) is
/// clamped, not silently granted extra scoped threads — logged once so
/// A/B runs don't chase phantom parallelism.
fn warn_oversubscribed(requested: usize, lanes: usize) {
    if !OVERSUBSCRIBE_WARNED.swap(true, Ordering::Relaxed) {
        eprintln!(
            "quantnmt: {requested} GEMM threads requested but the worker pool has {lanes} \
             lane(s); clamping (resize with --gemm-pool / QUANTNMT_GEMM_POOL)"
        );
    }
}

/// Run `f(j0, j1)` over the column stripes of `[0, n)`.
///
/// Callers pass a closure writing **disjoint** column ranges of C via a
/// [`SendPtr`]; with the per-element summation order fixed inside each
/// kernel, the output is bit-identical for every `threads` value and
/// both dispatch paths (pooled / scoped).
pub(crate) fn run_cols<F>(threads: usize, n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    run_striped(threads, n, STRIPE_ALIGN, f)
}

/// Row-axis twin of [`run_cols`]: `f(i0, i1)` over row stripes of
/// `[0, m)`, for tall-skinny shapes where the column axis can't feed
/// the workers (see [`plan_partition`]).
pub(crate) fn run_rows<F>(threads: usize, m: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    run_striped(threads, m, ROW_STRIPE_ALIGN, f)
}

/// Fan `f` out over aligned stripes of `[0, len)`: on the persistent
/// pool when enabled (clamping `threads` to the pool width), else one
/// scoped thread per stripe with the first stripe on the caller.
fn run_striped<F>(threads: usize, len: usize, align: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if threads <= 1 || len == 0 {
        f(0, len);
        return;
    }
    if let Some(pool) = super::pool::get() {
        let lanes = pool.lanes();
        if threads > lanes {
            warn_oversubscribed(threads, lanes);
        }
        let t = threads.min(lanes);
        if t <= 1 {
            f(0, len);
        } else {
            pool.run(t, len, align, &f);
        }
        return;
    }
    // --gemm-pool off: the legacy per-call scoped spawn.
    let ranges = stripe_ranges_with(len, threads, align);
    if ranges.len() <= 1 {
        f(0, len);
        return;
    }
    crossbeam_utils::thread::scope(|scope| {
        for &(j0, j1) in ranges.iter().skip(1) {
            let f = &f;
            scope.spawn(move |_| f(j0, j1));
        }
        f(ranges[0].0, ranges[0].1);
    })
    .expect("gemm worker thread panicked");
}

/// Raw mutable base pointer that may cross worker-thread boundaries.
///
/// Safety contract: every worker receiving a copy writes a disjoint
/// region (the [`run_cols`] / [`run_rows`] stripes), and the pointee
/// outlives the dispatch (the pool retires a job before `run` returns;
/// `crossbeam_utils::thread::scope` joins before the caller's borrow
/// ends on the fallback path).
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(pub *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isa_ladder_orders() {
        assert!(IsaLevel::Scalar < IsaLevel::Avx2);
        assert!(IsaLevel::Avx2 < IsaLevel::Avx512Vnni);
        // env override caps, never raises
        assert_eq!(IsaLevel::Avx512Vnni.min(IsaLevel::Avx2), IsaLevel::Avx2);
    }

    #[test]
    fn parse_isa_values() {
        assert_eq!(parse_isa("scalar"), Some(IsaLevel::Scalar));
        assert_eq!(parse_isa("portable"), Some(IsaLevel::Scalar));
        assert_eq!(parse_isa(" AVX2 "), Some(IsaLevel::Avx2));
        assert_eq!(parse_isa("vnni"), Some(IsaLevel::Avx512Vnni));
        assert_eq!(parse_isa("avx512vnni"), Some(IsaLevel::Avx512Vnni));
        assert_eq!(parse_isa("mmx"), None);
    }

    #[test]
    fn isa_level_capped_by_hardware() {
        // whatever the env says, the cached level can't exceed hardware
        assert!(isa_level() <= detect_isa());
    }

    #[test]
    fn stripes_align_and_cover() {
        for (n, t) in [(1usize, 4usize), (31, 2), (32, 2), (97, 3), (512, 4), (513, 7)] {
            let r = stripe_ranges(n, t);
            assert!(r.len() <= t.max(1));
            assert_eq!(r.first().unwrap().0, 0);
            assert_eq!(r.last().unwrap().1, n);
            for w in r.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            for &(j0, j1) in &r[..r.len() - 1] {
                assert_eq!((j1 - j0) % STRIPE_ALIGN, 0, "aligned stripe ({n},{t})");
            }
        }
        assert!(stripe_ranges(0, 3).is_empty());
    }

    #[test]
    fn effective_threads_gates_by_dispatch_cost() {
        // tiny shapes never thread on either dispatch path
        assert_eq!(effective_threads(0, 1, 64, 64), 1);
        // explicit request is honored but clamped to stripe count
        assert_eq!(effective_threads(4, 1, 8, 33), 2);
        assert_eq!(effective_threads(2, 1, 8, 8), 1);
        // the decode logits shape (m=1, k=512, n=512, ~0.5M flops):
        // parallel under pooled dispatch, single-threaded when every
        // call pays a scoped spawn (QUANTNMT_GEMM_PAR_MIN overrides
        // both, so only assert when it's unset)
        if std::env::var("QUANTNMT_GEMM_PAR_MIN").is_err() {
            let t = effective_threads(0, 1, 512, 512);
            if !super::super::pool::enabled() {
                assert_eq!(t, 1, "scoped path keeps the spawn-cost crossover");
            } else if gemm_threads() > 1 {
                assert!(t > 1, "pooled path should parallelize decode shapes");
            }
        }
    }

    #[test]
    fn plan_partition_picks_axis_by_shape() {
        // wide output: column stripes, clamped to the stripe count
        assert_eq!(plan_partition(4, 8, 64, 512), Partition::Cols(4));
        assert_eq!(plan_partition(4, 1, 8, 33), Partition::Cols(2));
        // tall-skinny: too few column stripes, plenty of rows
        assert_eq!(plan_partition(4, 256, 64, 24), Partition::Rows(4));
        // tall but with enough columns: stays on the column axis
        assert_eq!(plan_partition(4, 256, 64, 256), Partition::Cols(4));
        // tall-skinny but too few rows per worker: columns win
        assert_eq!(plan_partition(4, 16, 64, 24), Partition::Cols(1));
        // narrow output with just enough rows for two workers
        assert_eq!(plan_partition(2, 64, 64, 16), Partition::Rows(2));
        // gated-off small shapes run inline regardless of axis
        assert_eq!(plan_partition(0, 2, 4, 4), Partition::Cols(1));
    }

    #[test]
    fn stripe_ranges_with_align_covers() {
        for (len, t, align) in [(100usize, 4usize, 4usize), (7, 3, 1), (256, 4, 32), (9, 4, 4)] {
            let r = stripe_ranges_with(len, t, align);
            assert_eq!(r.first().unwrap().0, 0);
            assert_eq!(r.last().unwrap().1, len);
            for w in r.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
            for &(a, b) in &r[..r.len() - 1] {
                assert_eq!((b - a) % align, 0, "({len},{t},{align})");
            }
        }
    }

    #[test]
    fn pack_crossover_shape_aware() {
        assert!(!pack_pays(1, 4096), "m == 1 never repacks on the fly");
        assert!(!pack_pays(2, 128), "tiny tiles stay portable");
        assert!(pack_pays(2, 256));
        assert!(pack_pays(64, 64));
    }

    #[test]
    fn run_cols_covers_all_columns() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let n = 100;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        run_cols(4, n, |j0, j1| {
            for h in &hits[j0..j1] {
                h.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }
}
