//! AVX2 int8 GEMM tier: exact 256-bit `s8 x u8 -> i32` without VNNI.
//!
//! There is no 4-byte dot instruction below AVX-512 VNNI, and the
//! obvious `pmaddubsw` route saturates its i16 intermediate (u8*s8
//! pairs can exceed 32767), silently corrupting real activations.  We
//! instead split each packed B quad into its even and odd bytes,
//! widened to i16, and use `pmaddwd` (`_mm256_madd_epi16`), which is
//! exact here:
//!
//! * lane bytes `[b0 b1 b2 b3]` viewed as two i16s; `and 0x00FF` gives
//!   the even pair `[b0, b2]`, `srl 8` the odd pair `[b1, b3]` — all
//!   in `0..=255`, so non-negative i16;
//! * A is pre-packed ([`pack_a`]) as two broadcast words per quad: the
//!   sign-extended i16 pairs `[a0, a2]` and `[a1, a3]`;
//! * `madd(b_even, a02) + madd(b_odd, a13)` = the full quad dot.
//!   Each product is at most `255 * 128 = 32640` in magnitude and
//!   `pmaddwd` adds *two* of them into an i32 — no saturation, exact
//!   for every input.
//!
//! The macro-kernel mirrors the VNNI tier ([`super::vnni`]): MR=4 rows
//! by 2 ymm (16 lanes) register tiles over the shared [`PackedB`]
//! panel, with the same KC/NC blocking and column-stripe threading.

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

use super::pack::PackedB;
#[cfg(target_arch = "x86_64")]
use super::{KC_QUADS, NC_LANES};

/// Accumulator tile rows (4 rows x 2 ymm accumulators = 8 of the 16
/// ymm registers, leaving room for the 4 split-B vectors).
pub const MR: usize = 4;

/// Pack `a [m, k]` (s8) for the AVX2 kernel: per (quad, row), two i32
/// broadcast words holding the sign-extended i16 pairs `[a0, a2]` and
/// `[a1, a3]`, zero-padded at the k tail (zero pairs are neutral
/// before the zero-point correction).  Layout: `out[(quad*m + row)*2]`
/// and `out[(quad*m + row)*2 + 1]`.
pub fn pack_a(a: &[i8], m: usize, k: usize, out: &mut Vec<i32>) {
    assert_eq!(a.len(), m * k);
    let kp = k.div_ceil(4);
    out.clear();
    out.resize(kp * m * 2, 0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for quad in 0..kp {
            let base = quad * 4;
            let take = (k - base).min(4);
            let mut q = [0i16; 4];
            for (x, &av) in q.iter_mut().zip(&arow[base..base + take]) {
                *x = av as i16;
            }
            let o = (quad * m + i) * 2;
            out[o] = (q[0] as u16 as u32 | ((q[2] as u16 as u32) << 16)) as i32;
            out[o + 1] = (q[1] as u16 as u32 | ((q[3] as u16 as u32) << 16)) as i32;
        }
    }
}

/// Tiled AVX2 macro-kernel over columns `[j0, j1)` of the packed
/// panel; A pre-packed by [`pack_a`].  Overwrites C (no pre-zero
/// needed): the first k-block stores, later blocks accumulate.
///
/// # Safety
/// Requires AVX2 (callers dispatch via `gemm::avx2_available`).
/// `cbase` must point at an `m * bp.n` i32 buffer; concurrent callers
/// must write disjoint `[j0, j1)` ranges.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn igemm_avx2_tiled(
    m: usize,
    apack: &[i32],
    bp: &PackedB,
    cbase: *mut i32,
    j0: usize,
    j1: usize,
) {
    tiled_rect(m, apack, bp, cbase, 0, m, j0, j1)
}

/// Row-stripe twin of [`igemm_avx2_tiled`]: rows `[i0, i1)` over the
/// full column range, for tall-skinny shapes (`dispatch::run_rows`).
/// The A panel ([`pack_a`]) is indexed by absolute row, so a row
/// sub-range needs no repacking; row grouping never changes any
/// element's k-summation order, so the output is bit-identical to the
/// column-striped and single-threaded paths.
///
/// # Safety
/// As [`igemm_avx2_tiled`], with concurrent callers writing disjoint
/// `[i0, i1)` row ranges instead.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn igemm_avx2_tiled_rows(
    m: usize,
    apack: &[i32],
    bp: &PackedB,
    cbase: *mut i32,
    i0: usize,
    i1: usize,
) {
    tiled_rect(m, apack, bp, cbase, i0, i1, 0, bp.n)
}

/// Shared macro-loop over the `[i0, i1) x [j0, j1)` output rectangle.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn tiled_rect(
    m: usize,
    apack: &[i32],
    bp: &PackedB,
    cbase: *mut i32,
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
) {
    debug_assert_eq!(apack.len(), bp.kp * m * 2);
    debug_assert!(i1 <= m);
    debug_assert!(j1 <= bp.n);
    let kp = bp.kp;
    let mut jc = j0;
    while jc < j1 {
        let jl = (jc + NC_LANES).min(j1);
        let mut pc = 0;
        loop {
            let kq = (kp - pc).min(KC_QUADS);
            let first = pc == 0;
            let mut i = i0;
            while i < i1 {
                let mr = (i1 - i).min(MR);
                let mut jt = jc;
                while jt < jl {
                    match mr {
                        1 => tile::<1>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                        2 => tile::<2>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                        3 => tile::<3>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                        _ => tile::<4>(m, apack, bp, pc, kq, i, jt, cbase, jl, first),
                    }
                    jt += 16;
                }
                i += mr;
            }
            pc += kq;
            if pc >= kp {
                break;
            }
        }
        jc = jl;
    }
}

/// One MR x 16-lane register tile over quads `[pc, pc+kq)`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn tile<const R: usize>(
    m: usize,
    apack: &[i32],
    bp: &PackedB,
    pc: usize,
    kq: usize,
    i: usize,
    jt: usize,
    cbase: *mut i32,
    jlim: usize,
    first: bool,
) {
    let np = bp.np;
    let n = bp.n;
    let bdata = bp.data.as_ptr();
    let mask16 = _mm256_set1_epi16(0x00FF);
    let mut acc0 = [_mm256_setzero_si256(); R];
    let mut acc1 = [_mm256_setzero_si256(); R];
    for quad in pc..pc + kq {
        let bptr = bdata.add((quad * np + jt) * 4);
        let bv0 = _mm256_loadu_si256(bptr as *const _);
        let bv1 = _mm256_loadu_si256(bptr.add(32) as *const _);
        let b0_even = _mm256_and_si256(bv0, mask16);
        let b0_odd = _mm256_srli_epi16::<8>(bv0);
        let b1_even = _mm256_and_si256(bv1, mask16);
        let b1_odd = _mm256_srli_epi16::<8>(bv1);
        let ap = apack.as_ptr().add((quad * m + i) * 2);
        for r in 0..R {
            let a02 = _mm256_set1_epi32(*ap.add(r * 2));
            let a13 = _mm256_set1_epi32(*ap.add(r * 2 + 1));
            let e = _mm256_madd_epi16(b0_even, a02);
            let o = _mm256_madd_epi16(b0_odd, a13);
            acc0[r] = _mm256_add_epi32(acc0[r], _mm256_add_epi32(e, o));
            let e = _mm256_madd_epi16(b1_even, a02);
            let o = _mm256_madd_epi16(b1_odd, a13);
            acc1[r] = _mm256_add_epi32(acc1[r], _mm256_add_epi32(e, o));
        }
    }
    for r in 0..R {
        let row = cbase.add((i + r) * n);
        store8(row.add(jt), acc0[r], jlim as isize - jt as isize, first);
        store8(row.add(jt + 8), acc1[r], jlim as isize - jt as isize - 8, first);
    }
}

/// Store/accumulate 8 lanes at `p`, clipped to `valid` columns.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn store8(p: *mut i32, v: __m256i, valid: isize, first: bool) {
    if valid >= 8 {
        if first {
            _mm256_storeu_si256(p as *mut _, v);
        } else {
            let prev = _mm256_loadu_si256(p as *const _);
            _mm256_storeu_si256(p as *mut _, _mm256_add_epi32(prev, v));
        }
    } else if valid > 0 {
        let mut tmp = [0i32; 8];
        _mm256_storeu_si256(tmp.as_mut_ptr() as *mut _, v);
        let dst = std::slice::from_raw_parts_mut(p, valid as usize);
        for (x, &t) in dst.iter_mut().zip(&tmp) {
            if first {
                *x = t;
            } else {
                *x += t;
            }
        }
    }
}

#[cfg(not(target_arch = "x86_64"))]
pub unsafe fn igemm_avx2_tiled(
    _m: usize,
    _apack: &[i32],
    _bp: &PackedB,
    _cbase: *mut i32,
    _j0: usize,
    _j1: usize,
) {
    unreachable!("avx2_available() is false on this arch")
}

#[cfg(not(target_arch = "x86_64"))]
pub unsafe fn igemm_avx2_tiled_rows(
    _m: usize,
    _apack: &[i32],
    _bp: &PackedB,
    _cbase: *mut i32,
    _i0: usize,
    _i1: usize,
) {
    unreachable!("avx2_available() is false on this arch")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{avx2_available, igemm_naive};
    use crate::util::prop::{check, gen};

    #[test]
    fn pack_a_pairs_layout() {
        // k = 5: one full quad + a padded tail quad
        let a: Vec<i8> = vec![1, -2, 3, -4, 5, 10, -20, 30, -40, 50];
        let mut out = Vec::new();
        pack_a(&a, 2, 5, &mut out);
        assert_eq!(out.len(), 2 * 2 * 2);
        // row 0, quad 0: pairs [1, 3] and [-2, -4]
        assert_eq!(out[0], 1 | (3 << 16));
        assert_eq!(out[1], (-2i16 as u16 as u32 | ((-4i16 as u16 as u32) << 16)) as i32);
        // row 1, quad 1 (index (quad*m + row)*2 = 6): pairs [50, 0], [0, 0]
        assert_eq!(out[6], 50);
        assert_eq!(out[7], 0);
    }

    #[test]
    fn avx2_tiled_matches_naive_prop() {
        if !avx2_available() {
            eprintln!("skipping: no AVX2");
            return;
        }
        check("avx2-tiled==naive", 0xA2A2, 48, |rng, case| {
            let (dm, dk, dn) = gen::gemm_dims(rng, 70);
            let (mut m, mut k, mut n) = (dm, dk, dn);
            match case % 4 {
                0 => m = 1,
                1 => n = (n / 32) * 32 + 1 + (n % 31),
                2 => k = (k / 4) * 4 + 1 + (k % 3),
                _ => {}
            }
            let a: Vec<i8> = (0..m * k).map(|_| rng.next_u64() as i8).collect();
            let b: Vec<u8> = (0..k * n).map(|_| rng.next_u64() as u8).collect();
            let bp = PackedB::pack(&b, k, n);
            let mut ap = Vec::new();
            pack_a(&a, m, k, &mut ap);
            let mut c = vec![0i32; m * n];
            unsafe { igemm_avx2_tiled(m, &ap, &bp, c.as_mut_ptr(), 0, n) };
            let mut want = vec![0i32; m * n];
            igemm_naive(m, k, n, &a, &b, &mut want);
            if c != want {
                return Err(format!("mismatch at ({m},{k},{n})"));
            }
            Ok(())
        });
    }

    #[test]
    fn avx2_tiled_rows_match_full_run() {
        if !avx2_available() {
            return;
        }
        // row-striped execution (uneven split, MR-misaligned boundary)
        // must be bit-identical to one full-range call
        let (m, k, n) = (23, 37, 21);
        let a: Vec<i8> = (0..m * k).map(|i| (i as i32 * 11 % 251 - 125) as i8).collect();
        let b: Vec<u8> = (0..k * n).map(|i| (i * 23 % 256) as u8).collect();
        let bp = PackedB::pack(&b, k, n);
        let mut ap = Vec::new();
        pack_a(&a, m, k, &mut ap);
        let mut want = vec![0i32; m * n];
        unsafe { igemm_avx2_tiled(m, &ap, &bp, want.as_mut_ptr(), 0, n) };
        let mut c = vec![0i32; m * n];
        for (i0, i1) in [(0usize, 3usize), (3, 14), (14, 23)] {
            unsafe { igemm_avx2_tiled_rows(m, &ap, &bp, c.as_mut_ptr(), i0, i1) };
        }
        assert_eq!(c, want);
    }

    #[test]
    fn avx2_extreme_values_no_saturation() {
        if !avx2_available() {
            return;
        }
        // the pmaddubsw route would saturate on these; madd must not
        let (m, k, n) = (2, 9, 17);
        let a = vec![-128i8; m * k];
        let b = vec![255u8; k * n];
        let bp = PackedB::pack(&b, k, n);
        let mut ap = Vec::new();
        pack_a(&a, m, k, &mut ap);
        let mut c = vec![0i32; m * n];
        unsafe { igemm_avx2_tiled(m, &ap, &bp, c.as_mut_ptr(), 0, n) };
        assert!(c.iter().all(|&x| x == -128 * 255 * k as i32));
    }
}
