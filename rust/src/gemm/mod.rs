//! GEMM substrate: blocked FP32 GEMM and the tiled, multi-ISA,
//! multi-threaded quantized GEMM.
//!
//! The paper's §5.2 replaces TensorFlow's GEMMLOWP int8 MatMul with
//! Intel MKL's `s8 x u8 -> s32` kernel and measures 3.7x (peak) / 2.4x
//! (average over the model's shapes) vs FP32 AVX-512 GEMM.  We cannot
//! link MKL, so both sides of that comparison are implemented here with
//! the same structure a real kernel library uses:
//!
//! * [`sgemm`] — cache-blocked, unrolled f32 GEMM (the "AVX-512 FP32"
//!   baseline; rustc auto-vectorizes the inner loop), stripe-parallel
//!   via [`sgemm_threads`];
//! * [`igemm`] — `i8 x u8 -> i32` over a runtime ISA ladder
//!   ([`IsaLevel`]): a register-tiled AVX-512 VNNI macro-kernel
//!   ([`vnni`]), an exact 256-bit AVX2 tier ([`avx2`]), and a portable
//!   blocked quad-MAC fallback — all consuming the same k/4-packed B
//!   panel ([`PackedB`]) and all bit-identical;
//! * zero-point corrected entry points matching `kernels/ref.py`.
//!
//! Large GEMMs fan out over disjoint output stripes — columns by
//! default, rows for tall-skinny shapes — on the persistent [`pool`]
//! worker team (`--gemm-pool` / `QUANTNMT_GEMM_POOL`; thread budget
//! from `--gemm-threads` / `QUANTNMT_GEMM_THREADS`), with a scoped
//! spawn fallback when the pool is disabled.  The near-zero dispatch
//! cost of the pool lets the parallel crossover sit ~32x lower
//! (`PAR_FLOPS_MIN_POOLED`), so decode-shape GEMMs (m = a few slots,
//! n = vocab) go parallel too.  Stripes own disjoint output ranges and
//! never change any element's k-summation order, so results are
//! bit-identical for every thread count, partition axis, and dispatch
//! path.
//!
//! `rust/benches/gemm.rs` regenerates Fig 3a (square sizes) and Fig 3b
//! (the Transformer's actual shapes) from these kernels across the
//! kernel x thread grid and emits `BENCH_gemm.json`.

pub mod avx2;
mod dispatch;
mod igemm;
mod pack;
mod pool;
mod requant;
mod sgemm;
pub mod vnni;

pub use dispatch::{
    avx2_available, detect_isa, gemm_threads, isa_level, parse_isa, set_gemm_threads, IsaLevel,
    AUTO_PACK_MIN_MN, AUTO_PACK_MIN_ROWS, DEFAULT_MAX_THREADS, PAR_FLOPS_MIN,
    PAR_FLOPS_MIN_POOLED, ROW_STRIPE_ALIGN, ROW_STRIPE_MIN, STRIPE_ALIGN,
};
pub use pool::{gemm_pool_lanes, parse_pool_mode, set_gemm_pool, PoolMode};
pub use igemm::{
    apply_zero_corrections, dequantize_s8, igemm, igemm_corrected, igemm_corrected_scratch,
    igemm_portable, igemm_prepacked, igemm_prepacked_scratch, igemm_scratch, igemm_with,
    igemm_with_threads, quantize_s8, quantize_u8, quantized_matmul, use_vnni, KernelChoice,
    PackScratch, QGemmScratch,
};
pub use pack::{PackedB, VNNI_LANES};
pub use requant::{
    igemm_requant_prepacked_s8, igemm_requant_prepacked_u8, igemm_requant_s8, igemm_requant_u8,
    requant_epilogue_residual, requant_epilogue_s8, requant_epilogue_u8, RequantParams,
};
pub use sgemm::{sgemm, sgemm_threads};

/// Cache-block depth of the tiled kernels, in k-quads (1024 k-rows per
/// block: the packed panel slice an NC-wide block keeps hot in L2).
pub(crate) const KC_QUADS: usize = 256;
/// Cache-block width of the tiled kernels, in output columns.
pub(crate) const NC_LANES: usize = 256;

use crate::tensor::TensorF;

/// The u8 zero point for the B operand (mirrors python common.py).
pub const UINT8_ZERO_POINT: i32 = 128;

/// f32 matmul over [`TensorF`]s: `[m,k] x [k,n] -> [m,n]`.
pub fn matmul(a: &TensorF, b: &TensorF) -> TensorF {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul {:?} x {:?}", a.shape(), b.shape());
    let mut out = TensorF::zeros(&[m, n]);
    sgemm(m, k, n, a.data(), b.data(), out.data_mut());
    out
}

/// Reference (naive triple-loop) f32 GEMM for correctness checks.
pub fn matmul_naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            c[i * n + j] = acc;
        }
    }
}

/// Reference int GEMM (i32 math throughout) for correctness checks.
pub fn igemm_naive(m: usize, k: usize, n: usize, a: &[i8], b: &[u8], c: &mut [i32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i32;
            for p in 0..k {
                acc += a[i * k + p] as i32 * b[p * n + j] as i32;
            }
            c[i * n + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, gen};
    use crate::util::rng::SplitMix64;

    #[test]
    fn matmul_tensor_wrapper() {
        let a = TensorF::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = TensorF::from_vec(&[2, 2], vec![1., 0., 0., 1.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), a.data());
    }

    #[test]
    fn sgemm_matches_naive_prop() {
        check("sgemm==naive", 11, 40, |rng, _| {
            let (m, k, n) = gen::gemm_dims(rng, 48);
            let mut a = vec![0.0f32; m * k];
            let mut b = vec![0.0f32; k * n];
            rng.fill_uniform_f32(&mut a, 2.0);
            rng.fill_uniform_f32(&mut b, 2.0);
            let mut c1 = vec![0.0f32; m * n];
            let mut c2 = vec![0.0f32; m * n];
            sgemm(m, k, n, &a, &b, &mut c1);
            matmul_naive(m, k, n, &a, &b, &mut c2);
            for (x, y) in c1.iter().zip(&c2) {
                if (x - y).abs() > 1e-3 * (1.0 + y.abs()) {
                    return Err(format!("({m},{k},{n}): {x} vs {y}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn igemm_matches_naive_prop() {
        check("igemm==naive", 13, 40, |rng, _| {
            let (m, k, n) = gen::gemm_dims(rng, 48);
            let a: Vec<i8> = (0..m * k).map(|_| rng.next_u64() as i8).collect();
            let b: Vec<u8> = (0..k * n).map(|_| rng.next_u64() as u8).collect();
            let mut c1 = vec![0i32; m * n];
            let mut c2 = vec![0i32; m * n];
            igemm(m, k, n, &a, &b, &mut c1);
            igemm_naive(m, k, n, &a, &b, &mut c2);
            if c1 != c2 {
                return Err(format!("mismatch at ({m},{k},{n})"));
            }
            Ok(())
        });
    }

    #[test]
    fn igemm_saturating_inputs() {
        // extreme values must not overflow i32 for realistic k
        let m = 2;
        let k = 512;
        let n = 2;
        let a = vec![-128i8; m * k];
        let b = vec![255u8; k * n];
        let mut c = vec![0i32; m * n];
        igemm(m, k, n, &a, &b, &mut c);
        assert_eq!(c[0], -128 * 255 * 512);
    }

    #[test]
    fn degenerate_dims() {
        // k = 0 -> all zeros; m or n = 0 -> empty
        let mut c = vec![7.0f32; 4];
        sgemm(2, 0, 2, &[], &[], &mut c);
        assert_eq!(c, vec![0.0; 4]);
        let mut ci = vec![7i32; 0];
        igemm(0, 3, 0, &[], &[], &mut ci);
    }

    #[test]
    fn quantized_matmul_matches_float_within_step() {
        // quantize -> igemm -> dequantize must track the float product
        let mut rng = SplitMix64::new(5);
        let (m, k, n) = (9, 33, 7);
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        rng.fill_uniform_f32(&mut a, 1.0);
        rng.fill_uniform_f32(&mut b, 1.0);
        let sa = 1.0 / 127.0;
        let sb = 1.0 / 127.0;
        let mut out = vec![0.0f32; m * n];
        let mut scratch = QGemmScratch::default();
        quantized_matmul(m, k, n, &a, sa, 0, &b, sb, &mut out, &mut scratch);
        let mut exact = vec![0.0f32; m * n];
        matmul_naive(m, k, n, &a, &b, &mut exact);
        // error bound: k * (sa/2 * |b|max + sb/2 * |a|max + sa*sb/4)
        let bound = k as f32 * (sa * 0.5 + sb * 0.5 + sa * sb * 0.25) * 1.5;
        for (o, e) in out.iter().zip(&exact) {
            assert!((o - e).abs() <= bound, "{o} vs {e} (bound {bound})");
        }
    }
}
