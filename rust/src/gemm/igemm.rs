//! Quantized GEMM entry points: `s8 x u8 -> i32` over the ISA ladder.
//!
//! Cascade Lake's `vpdpbusd` fuses 4 u8*s8 products + i32 add into one
//! instruction per lane; GEMMLOWP (what stock TensorFlow used) does the
//! same arithmetic scalar-by-scalar, which is why the paper swapped in
//! MKL's kernel.  This module is the front door: it resolves a
//! [`KernelChoice`] against the cached [`super::dispatch::isa_level`],
//! packs operands into the scratch the caller provides, and fans the
//! macro-loop out over column stripes ([`super::dispatch::run_cols`]).
//! The kernels themselves live in [`super::vnni`] (512-bit tiled),
//! [`super::avx2`] (256-bit tiled) and [`super::pack`] /
//! [`igemm_portable`] (scalar).
//!
//! Every path computes the identical integer result — dispatch changes
//! speed, never values — and threading partitions *output columns*, so
//! results are bit-identical for every thread count.
//!
//! Entry points:
//! * [`igemm`] / [`igemm_with`] / [`igemm_with_threads`] — raw
//!   `A_s8 [m,k] * B_u8 [k,n] -> C_i32 [m,n]` (allocating variants)
//! * [`igemm_scratch`] / [`igemm_prepacked_scratch`] — the same against
//!   caller-owned [`PackScratch`] buffers (the engine hot path)
//! * [`igemm_corrected`] / [`igemm_corrected_scratch`] — subtract the
//!   zero-point corrections ([`apply_zero_corrections`])
//! * [`quantized_matmul`] — full f32 -> int8 -> f32 path matching
//!   `python/compile/kernels/ref.py::fake_quant_matmul_ref`

use super::dispatch::{pack_pays, plan_partition, run_cols, run_rows, Partition, SendPtr};
use super::pack::PackedB;
use super::{IsaLevel, UINT8_ZERO_POINT};

const MC: usize = 64;
const KC: usize = 256;
const NC: usize = 512;

/// Explicit kernel selector for [`igemm_with`].
///
/// [`super::isa_level`] caches the detected/overridden ISA in a
/// `OnceLock`, so a single test binary could never exercise *both*
/// kernels through [`igemm`].  Passing a `KernelChoice` bypasses the
/// cached dispatch entirely, letting parity tests force every tier
/// side by side in one process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelChoice {
    /// cached runtime dispatch: the best available tier when the
    /// pack crossover says packing pays (what [`igemm`] does)
    Auto,
    /// force the portable blocked quad-MAC kernel
    Portable,
    /// force the 256-bit AVX2 tiled kernel, even for m == 1 (panics
    /// when the CPU lacks AVX2 — callers gate on
    /// [`super::avx2_available`])
    Avx2,
    /// force the AVX-512 VNNI tiled kernel, even for m == 1 (panics
    /// when the CPU lacks VNNI — callers gate on
    /// [`super::vnni::vnni_available`])
    Vnni,
}

/// The resolved execution tier for one call.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Tier {
    Portable,
    Avx2,
    Vnni,
}

/// Resolve a [`KernelChoice`] to a concrete tier for an `m x n` output.
/// Forced choices assert their hardware; `Auto` follows the cached
/// [`IsaLevel`] and, for unpacked operands, the pack crossover
/// ([`pack_pays`]).
fn resolve_tier(choice: KernelChoice, m: usize, n: usize, prepacked: bool) -> Tier {
    match choice {
        KernelChoice::Portable => Tier::Portable,
        KernelChoice::Avx2 => {
            assert!(
                super::dispatch::avx2_available(),
                "KernelChoice::Avx2 forced on a CPU without AVX2"
            );
            Tier::Avx2
        }
        KernelChoice::Vnni => {
            assert!(
                super::vnni::vnni_available(),
                "KernelChoice::Vnni forced on a CPU without AVX-512 VNNI"
            );
            Tier::Vnni
        }
        KernelChoice::Auto => match super::dispatch::isa_level() {
            IsaLevel::Scalar => Tier::Portable,
            // Shape-aware kernel choice (§5.2): packing B costs one
            // O(k*n) pass, amortized over the m x n output tile — the
            // paper likewise picks kernels by matrix shape.  Prepacked
            // panels paid that cost at plan-compile time.
            IsaLevel::Avx2 if prepacked || pack_pays(m, n) => Tier::Avx2,
            IsaLevel::Avx512Vnni if prepacked || pack_pays(m, n) => Tier::Vnni,
            _ => Tier::Portable,
        },
    }
}

/// Reusable packing/correction buffers for the int8 GEMM path, so the
/// engine's hot loop packs in place instead of allocating: the
/// activation-side B panel (QK^T / probs x V repack every call), the
/// tiled kernels' A panel, and the zero-point `colsum`.
#[derive(Default)]
pub struct PackScratch {
    pub b_pack: PackedB,
    pub a_pack: Vec<i32>,
    pub colsum: Vec<i32>,
}

/// `c = a * b` with i32 accumulation (c fully overwritten).
///
/// Dispatches over the cached ISA level, packing B on the fly when the
/// shape crossover says it pays; otherwise runs the portable blocked
/// quad-MAC kernel.
pub fn igemm(m: usize, k: usize, n: usize, a: &[i8], b: &[u8], c: &mut [i32]) {
    igemm_with(KernelChoice::Auto, m, k, n, a, b, c);
}

/// [`igemm`] with an explicit kernel choice (see [`KernelChoice`]).
pub fn igemm_with(
    choice: KernelChoice,
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    b: &[u8],
    c: &mut [i32],
) {
    igemm_with_threads(choice, 0, m, k, n, a, b, c);
}

/// [`igemm_with`] with an explicit worker count (`0` = the process
/// default, gated by the flops threshold).  Allocates its own packing
/// buffers; the engine uses [`igemm_scratch`].
#[allow(clippy::too_many_arguments)]
pub fn igemm_with_threads(
    choice: KernelChoice,
    threads: usize,
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    b: &[u8],
    c: &mut [i32],
) {
    let mut ws = PackScratch::default();
    igemm_scratch(choice, threads, m, k, n, a, b, c, &mut ws);
}

/// Core unpacked entry point: `c = a * b` using `ws` for every
/// intermediate buffer.  `threads == 0` means the process default
/// ([`super::gemm_threads`]) gated by the flops threshold; an explicit
/// count is honored (tests and benches sweep it).
#[allow(clippy::too_many_arguments)]
pub fn igemm_scratch(
    choice: KernelChoice,
    threads: usize,
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    b: &[u8],
    c: &mut [i32],
    ws: &mut PackScratch,
) {
    assert_eq!(a.len(), m * k, "a len");
    assert_eq!(b.len(), k * n, "b len");
    assert_eq!(c.len(), m * n, "c len");
    if m == 0 || k == 0 || n == 0 {
        c.fill(0);
        return;
    }
    match resolve_tier(choice, m, n, false) {
        Tier::Portable => {
            c.fill(0);
            let cp = SendPtr(c.as_mut_ptr());
            match plan_partition(threads, m, k, n) {
                Partition::Cols(t) => run_cols(t, n, |j0, j1| {
                    // SAFETY: stripes write disjoint columns of c.
                    unsafe { portable_cols(m, k, n, a, b, cp.0, j0, j1) }
                }),
                Partition::Rows(t) => run_rows(t, m, |i0, i1| {
                    // SAFETY: stripes write disjoint rows of c.
                    unsafe { portable_rows(k, n, a, b, cp.0, i0, i1) }
                }),
            }
        }
        tier => {
            ws.b_pack.pack_into(b, k, n);
            packed_tier(tier, threads, m, k, a, &ws.b_pack, &mut ws.a_pack, c);
        }
    }
}

/// `c = a * B_packed` against a pre-packed B (weights are packed once).
/// Allocating compatibility wrapper over [`igemm_prepacked_scratch`].
pub fn igemm_prepacked(m: usize, k: usize, a: &[i8], bp: &PackedB, c: &mut [i32]) {
    let mut a_pack = Vec::new();
    igemm_prepacked_scratch(KernelChoice::Auto, 0, m, k, a, bp, c, &mut a_pack);
}

/// `c = a * B_packed` with explicit kernel choice, worker count and a
/// caller-owned A-panel buffer (the engine hot path for weight GEMMs).
#[allow(clippy::too_many_arguments)]
pub fn igemm_prepacked_scratch(
    choice: KernelChoice,
    threads: usize,
    m: usize,
    k: usize,
    a: &[i8],
    bp: &PackedB,
    c: &mut [i32],
    a_pack: &mut Vec<i32>,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(c.len(), m * bp.n);
    assert_eq!(bp.k, k, "packed panel k mismatch");
    if m == 0 || k == 0 || bp.n == 0 {
        c.fill(0);
        return;
    }
    let tier = resolve_tier(choice, m, bp.n, true);
    packed_tier(tier, threads, m, k, a, bp, a_pack, c);
}

/// Shared macro-loop over a packed panel: pack A for the tier, then fan
/// the tiled kernel out over column stripes (or row stripes for
/// tall-skinny shapes — the quad-major A panels index rows absolutely,
/// so both axes read the same panel).
fn packed_tier(
    tier: Tier,
    threads: usize,
    m: usize,
    k: usize,
    a: &[i8],
    bp: &PackedB,
    a_pack: &mut Vec<i32>,
    c: &mut [i32],
) {
    let n = bp.n;
    let part = plan_partition(threads, m, k, n);
    let cp = SendPtr(c.as_mut_ptr());
    match tier {
        Tier::Portable => {
            // scalar tier over the packed layout (e.g. forced Portable
            // against a prepacked weight, or QUANTNMT_ISA=scalar)
            c.fill(0);
            match part {
                Partition::Cols(t) => run_cols(t, n, |j0, j1| {
                    // SAFETY: stripes write disjoint columns of c.
                    unsafe { super::pack::igemm_packed_scalar(m, k, a, bp, cp.0, j0, j1) }
                }),
                Partition::Rows(t) => run_rows(t, m, |i0, i1| {
                    // SAFETY: stripes write disjoint rows of c.
                    unsafe { super::pack::igemm_packed_scalar_rows(m, k, a, bp, cp.0, i0, i1) }
                }),
            }
        }
        Tier::Avx2 => {
            super::avx2::pack_a(a, m, k, a_pack);
            let ap: &[i32] = a_pack;
            match part {
                Partition::Cols(t) => run_cols(t, n, |j0, j1| {
                    // SAFETY: AVX2 asserted by resolve_tier; disjoint stripes.
                    unsafe { super::avx2::igemm_avx2_tiled(m, ap, bp, cp.0, j0, j1) }
                }),
                Partition::Rows(t) => run_rows(t, m, |i0, i1| {
                    // SAFETY: AVX2 asserted by resolve_tier; disjoint row stripes.
                    unsafe { super::avx2::igemm_avx2_tiled_rows(m, ap, bp, cp.0, i0, i1) }
                }),
            }
        }
        Tier::Vnni => {
            super::vnni::pack_a(a, m, k, a_pack);
            let ap: &[i32] = a_pack;
            match part {
                Partition::Cols(t) => run_cols(t, n, |j0, j1| {
                    // SAFETY: VNNI asserted by resolve_tier; disjoint stripes.
                    unsafe { super::vnni::igemm_vnni_tiled(m, ap, bp, cp.0, j0, j1) }
                }),
                Partition::Rows(t) => run_rows(t, m, |i0, i1| {
                    // SAFETY: VNNI asserted by resolve_tier; disjoint row stripes.
                    unsafe { super::vnni::igemm_vnni_tiled_rows(m, ap, bp, cp.0, i0, i1) }
                }),
            }
        }
    }
}

/// Cached "best tier is VNNI" check — kept for callers (and the golden
/// parity harness) that predate the [`IsaLevel`] ladder.
pub fn use_vnni() -> bool {
    super::dispatch::isa_level() == IsaLevel::Avx512Vnni
}

/// Portable blocked kernel (also the reference for the SIMD paths).
/// Accumulates into `c` (callers zero it first).
pub fn igemm_portable(m: usize, k: usize, n: usize, a: &[i8], b: &[u8], c: &mut [i32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    // SAFETY: single caller owns all of c.
    unsafe { portable_cols(m, k, n, a, b, c.as_mut_ptr(), 0, n) }
}

/// Portable kernel over columns `[j0, j1)`: the blocked macro-loop
/// restricted to one stripe.
///
/// # Safety
/// `cbase` must point at an `m * n` i32 buffer; concurrent callers must
/// write disjoint `[j0, j1)` ranges.
unsafe fn portable_cols(
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    b: &[u8],
    cbase: *mut i32,
    j0: usize,
    j1: usize,
) {
    let mut jc = j0;
    while jc < j1 {
        let nb = NC.min(j1 - jc);
        for pc in (0..k).step_by(KC) {
            let kb = KC.min(k - pc);
            for ic in (0..m).step_by(MC) {
                let mb = MC.min(m - ic);
                block(k, n, a, b, cbase, ic, pc, jc, mb, kb, nb);
            }
        }
        jc += nb;
    }
}

/// Row-stripe twin of [`portable_cols`]: rows `[i0, i1)` over the full
/// column range, for tall-skinny shapes (`dispatch::run_rows`).  The
/// k-block order (and so every element's summation order) is identical
/// to [`portable_cols`], so any row partition is bit-identical to the
/// single-range call.
///
/// # Safety
/// `cbase` must point at an `m * n` i32 buffer; concurrent callers must
/// write disjoint `[i0, i1)` row ranges.
unsafe fn portable_rows(
    k: usize,
    n: usize,
    a: &[i8],
    b: &[u8],
    cbase: *mut i32,
    i0: usize,
    i1: usize,
) {
    let mut jc = 0;
    while jc < n {
        let nb = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kb = KC.min(k - pc);
            let mut ic = i0;
            while ic < i1 {
                let mb = MC.min(i1 - ic);
                block(k, n, a, b, cbase, ic, pc, jc, mb, kb, nb);
                ic += mb;
            }
        }
        jc += nb;
    }
}

/// Register-tiled micro-kernel.
///
/// Output tiles of NR=32 i32 lanes (two zmm registers on AVX-512) are
/// accumulated in a stack tile across the whole k-block before touching
/// C — the same register-blocking MKL's VNNI kernel uses, with the
/// quad-MAC inner statement (4 byte products into an i32 lane) that
/// `vpdpbusd` hard-wires.
const NR: usize = 32;

#[inline]
#[allow(clippy::too_many_arguments)]
unsafe fn block(
    k: usize,
    n: usize,
    a: &[i8],
    b: &[u8],
    cbase: *mut i32,
    ic: usize,
    pc: usize,
    jc: usize,
    mb: usize,
    kb: usize,
    nb: usize,
) {
    let mut j = 0;
    while j < nb {
        let nr = NR.min(nb - j);
        if nr == NR {
            for i in 0..mb {
                let r = ic + i;
                let arow = &a[r * k + pc..r * k + pc + kb];
                let mut acc = [0i32; NR];
                let mut p = 0;
                // quad-unrolled k loop: one "software vpdpbusd" per 4 rows
                while p + 4 <= kb {
                    let a0 = arow[p] as i32;
                    let a1 = arow[p + 1] as i32;
                    let a2 = arow[p + 2] as i32;
                    let a3 = arow[p + 3] as i32;
                    let b0 = &b[(pc + p) * n + jc + j..][..NR];
                    let b1 = &b[(pc + p + 1) * n + jc + j..][..NR];
                    let b2 = &b[(pc + p + 2) * n + jc + j..][..NR];
                    let b3 = &b[(pc + p + 3) * n + jc + j..][..NR];
                    for x in 0..NR {
                        acc[x] += a0 * b0[x] as i32
                            + a1 * b1[x] as i32
                            + a2 * b2[x] as i32
                            + a3 * b3[x] as i32;
                    }
                    p += 4;
                }
                while p < kb {
                    let av = arow[p] as i32;
                    let brow = &b[(pc + p) * n + jc + j..][..NR];
                    for x in 0..NR {
                        acc[x] += av * brow[x] as i32;
                    }
                    p += 1;
                }
                // SAFETY: rows disjoint; [jc+j, jc+j+NR) is within this
                // caller's column stripe.
                let crow = std::slice::from_raw_parts_mut(cbase.add(r * n + jc + j), NR);
                for x in 0..NR {
                    crow[x] += acc[x];
                }
            }
        } else {
            // ragged right edge: plain quad-MAC into C
            for i in 0..mb {
                let r = ic + i;
                let arow = &a[r * k + pc..r * k + pc + kb];
                // SAFETY: as above, nr columns from jc+j.
                let crow = std::slice::from_raw_parts_mut(cbase.add(r * n + jc + j), nr);
                for (p, &av) in arow.iter().enumerate() {
                    let brow = &b[(pc + p) * n + jc + j..][..nr];
                    let av = av as i32;
                    for x in 0..nr {
                        crow[x] += av * brow[x] as i32;
                    }
                }
            }
        }
        j += nr;
    }
}

/// Subtract the zero-point corrections from a raw `A_q x B_q` product:
/// `acc -= 128*rowsum(a) + za*colsum(b) - k*za*128` — i.e. turn
/// `sum a*b` into `sum (a - za)(b - 128)` without materializing shifted
/// operands.  `colsum` is only read when `za != 0` (symmetric mode
/// keeps the offset zero to skip it, paper §4.2), so callers may pass
/// an empty slice then; quantized weights carry a precomputed one.
pub fn apply_zero_corrections(
    rows: usize,
    k: usize,
    n: usize,
    a_q: &[i8],
    a_zero: i32,
    colsum: &[i32],
    acc: &mut [i32],
) {
    let kz = k as i32 * a_zero * UINT8_ZERO_POINT;
    for i in 0..rows {
        let mut rowsum = 0i32;
        for p in 0..k {
            rowsum += a_q[i * k + p] as i32;
        }
        let corr_row = UINT8_ZERO_POINT * rowsum;
        let row = &mut acc[i * n..(i + 1) * n];
        if a_zero == 0 {
            for x in row.iter_mut() {
                *x -= corr_row;
            }
        } else {
            for (j, x) in row.iter_mut().enumerate() {
                *x = *x - corr_row - a_zero * colsum[j] + kz;
            }
        }
    }
}

/// Zero-point-corrected int GEMM:
///
/// `out[m,n] = sum_k (a[m,k] - za) * (b[k,n] - 128)` computed as the raw
/// product minus row/col-sum corrections (one pass, no materialized
/// shifted operands).  Allocating wrapper over
/// [`igemm_corrected_scratch`].
pub fn igemm_corrected(
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    za: i32,
    b: &[u8],
    c: &mut [i32],
) {
    let mut ws = PackScratch::default();
    igemm_corrected_scratch(m, k, n, a, za, b, c, &mut ws);
}

/// [`igemm_corrected`] against caller-owned buffers: the packing panels
/// *and* the `colsum` correction live in `ws`, so the per-site hot loop
/// (QK^T, probs x V) performs no allocation.
#[allow(clippy::too_many_arguments)]
pub fn igemm_corrected_scratch(
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    za: i32,
    b: &[u8],
    c: &mut [i32],
    ws: &mut PackScratch,
) {
    igemm_scratch(KernelChoice::Auto, 0, m, k, n, a, b, c, ws);
    // colsum(b): [n] — only needed when za != 0 (paper §4.2: symmetric
    // mode keeps the offset zero to use the faster kernel)
    ws.colsum.clear();
    if za != 0 {
        ws.colsum.resize(n, 0);
        for p in 0..k {
            let brow = &b[p * n..(p + 1) * n];
            for (s, &bx) in ws.colsum.iter_mut().zip(brow) {
                *s += bx as i32;
            }
        }
    }
    apply_zero_corrections(m, k, n, a, za, &ws.colsum, c);
}

/// Reusable buffers for the quantize -> igemm -> dequantize path, so the
/// engine's hot loop performs no allocation (perf pass, EXPERIMENTS §Perf).
#[derive(Default)]
pub struct QGemmScratch {
    pub a_q: Vec<i8>,
    pub b_q: Vec<u8>,
    pub acc: Vec<i32>,
    /// packing panels + colsum for the int8 GEMM itself
    pub pack: PackScratch,
}

/// Full quantized MatMul: quantize A (s8, affine) and B (u8, zp 128),
/// multiply with i32 accumulation, dequantize to f32.
///
/// Matches `kernels/ref.py::fake_quant_matmul_ref` bit-for-bit in the
/// integer domain (float rounding of the final scale may differ in ulp).
#[allow(clippy::too_many_arguments)]
pub fn quantized_matmul(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    a_scale: f32,
    a_zero: i32,
    b: &[f32],
    b_scale: f32,
    out: &mut [f32],
    scratch: &mut QGemmScratch,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    scratch.a_q.resize(m * k, 0);
    scratch.b_q.resize(k * n, 0);
    scratch.acc.resize(m * n, 0);
    quantize_s8(a, a_scale, a_zero, &mut scratch.a_q);
    quantize_u8(b, b_scale, &mut scratch.b_q);
    igemm_corrected_scratch(
        m,
        k,
        n,
        &scratch.a_q,
        a_zero,
        &scratch.b_q,
        &mut scratch.acc,
        &mut scratch.pack,
    );
    let s = a_scale * b_scale;
    for (o, &acc) in out.iter_mut().zip(scratch.acc.iter()) {
        *o = acc as f32 * s;
    }
}

/// Quantize f32 -> s8 (paper eq. 5): `clip(round(x/scale) + zero, -128, 127)`.
pub fn quantize_s8(src: &[f32], scale: f32, zero: i32, dst: &mut [i8]) {
    assert_eq!(src.len(), dst.len());
    let inv = 1.0 / scale;
    for (d, &x) in dst.iter_mut().zip(src) {
        let q = (x * inv).round() as i32 + zero;
        *d = q.clamp(-128, 127) as i8;
    }
}

/// Quantize f32 -> u8 with fixed zero point 128.
pub fn quantize_u8(src: &[f32], scale: f32, dst: &mut [u8]) {
    assert_eq!(src.len(), dst.len());
    let inv = 1.0 / scale;
    for (d, &x) in dst.iter_mut().zip(src) {
        let q = (x * inv).round() as i32 + UINT8_ZERO_POINT;
        *d = q.clamp(0, 255) as u8;
    }
}

/// Dequantize s8 -> f32 (paper eq. 6).
///
/// Hot on the boundary sites that stay FP32 next to a quantized
/// producer, so it dispatches to an AVX2 lane when available and an
/// unrolled portable loop otherwise.  Every path performs the identical
/// `(q - zero) as f32 * scale` — an exact i32 widen, exact small-int
/// f32 convert, and one f32 multiply — so outputs are bit-identical
/// across tiers (pinned by `dequantize_s8_tiers_bit_identical`).
pub fn dequantize_s8(src: &[i8], scale: f32, zero: i32, dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    #[cfg(target_arch = "x86_64")]
    if super::dispatch::avx2_available() && src.len() >= 8 {
        // SAFETY: AVX2 support checked at runtime.
        unsafe { dequantize_s8_avx2(src, scale, zero, dst) };
        return;
    }
    dequantize_s8_portable(src, scale, zero, dst);
}

/// Portable tier: 4x-unrolled scalar loop (the compiler keeps the four
/// independent convert/mul chains in flight; the rolled loop serializes
/// on a single accumulator-free chain but still bounds-checks per
/// element).
fn dequantize_s8_portable(src: &[i8], scale: f32, zero: i32, dst: &mut [f32]) {
    let n4 = src.len() / 4 * 4;
    let (s4, st) = src.split_at(n4);
    let (d4, dt) = dst.split_at_mut(n4);
    for (d, s) in d4.chunks_exact_mut(4).zip(s4.chunks_exact(4)) {
        d[0] = (s[0] as i32 - zero) as f32 * scale;
        d[1] = (s[1] as i32 - zero) as f32 * scale;
        d[2] = (s[2] as i32 - zero) as f32 * scale;
        d[3] = (s[3] as i32 - zero) as f32 * scale;
    }
    for (d, &q) in dt.iter_mut().zip(st) {
        *d = (q as i32 - zero) as f32 * scale;
    }
}

/// AVX2 tier: widen 8 lanes s8 -> i32, subtract the zero point in the
/// integer domain, convert, and scale with a plain multiply (no FMA, so
/// rounding matches the scalar path exactly).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dequantize_s8_avx2(src: &[i8], scale: f32, zero: i32, dst: &mut [f32]) {
    use std::arch::x86_64::*;
    let n8 = src.len() / 8 * 8;
    let zv = _mm256_set1_epi32(zero);
    let sv = _mm256_set1_ps(scale);
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let mut i = 0;
    while i < n8 {
        let bytes = _mm_loadl_epi64(sp.add(i) as *const _);
        let wide = _mm256_sub_epi32(_mm256_cvtepi8_epi32(bytes), zv);
        let f = _mm256_mul_ps(_mm256_cvtepi32_ps(wide), sv);
        _mm256_storeu_ps(dp.add(i), f);
        i += 8;
    }
    dequantize_s8_portable(&src[n8..], scale, zero, &mut dst[n8..]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, gen};

    #[test]
    fn kernel_choice_portable_forces_portable_path() {
        // works on every CPU: Portable and Auto must agree bit-for-bit
        let (m, k, n) = (3, 10, 33);
        let a: Vec<i8> = (0..m * k).map(|i| (i as i32 % 251 - 125) as i8).collect();
        let b: Vec<u8> = (0..k * n).map(|i| (i * 7 % 256) as u8).collect();
        let mut c_auto = vec![0i32; m * n];
        let mut c_port = vec![0i32; m * n];
        igemm(m, k, n, &a, &b, &mut c_auto);
        igemm_with(KernelChoice::Portable, m, k, n, &a, &b, &mut c_port);
        assert_eq!(c_auto, c_port);
    }

    /// VNNI (on-the-fly packed and prepacked) must equal the portable
    /// kernel *exactly* — integer math, so not "close", identical.
    /// Shapes deliberately sweep the kernel's edge regimes: m == 1
    /// (below the Auto heuristic), ragged n % 32 != 0 (partial NR tile
    /// / masked store) and k % 4 != 0 (padded A quad tail).
    #[test]
    fn prop_vnni_and_prepacked_match_portable_exactly() {
        if !super::super::vnni::vnni_available() {
            eprintln!("skipping: no AVX-512 VNNI");
            return;
        }
        check("vnni==portable", 0xAB12, 64, |rng, case| {
            let (dm, dk, dn) = gen::gemm_dims(rng, 80);
            let (mut m, mut k, mut n) = (dm, dk, dn);
            // force each edge regime on a rotating schedule (plus the
            // unconstrained random shapes on case % 4 == 3)
            match case % 4 {
                0 => m = 1,
                1 => n = (n / 32) * 32 + 1 + (n % 31), // n % 32 != 0
                2 => k = (k / 4) * 4 + 1 + (k % 3),    // k % 4 != 0
                _ => {}
            }
            let a: Vec<i8> = (0..m * k).map(|_| rng.next_u64() as i8).collect();
            let b: Vec<u8> = (0..k * n).map(|_| rng.next_u64() as u8).collect();
            let mut c_port = vec![0i32; m * n];
            igemm_with(KernelChoice::Portable, m, k, n, &a, &b, &mut c_port);
            let mut c_vnni = vec![0i32; m * n];
            igemm_with(KernelChoice::Vnni, m, k, n, &a, &b, &mut c_vnni);
            if c_vnni != c_port {
                return Err(format!("vnni != portable at ({m},{k},{n})"));
            }
            let bp = PackedB::pack(&b, k, n);
            let mut c_pre = vec![0i32; m * n];
            igemm_prepacked(m, k, &a, &bp, &mut c_pre);
            if c_pre != c_port {
                return Err(format!("prepacked != portable at ({m},{k},{n})"));
            }
            Ok(())
        });
    }

    /// The acceptance-criterion sweep: every available `KernelChoice`
    /// x {on-the-fly packed, prepacked} x {1, 2, 4} threads must
    /// produce bit-identical C over the rotating edge-shape schedule.
    #[test]
    fn prop_kernel_thread_cross_product_parity() {
        let mut choices = vec![KernelChoice::Portable];
        if super::super::dispatch::avx2_available() {
            choices.push(KernelChoice::Avx2);
        }
        if super::super::vnni::vnni_available() {
            choices.push(KernelChoice::Vnni);
        }
        check("kernel x threads cross product", 0xC805, 32, |rng, case| {
            let (dm, dk, dn) = gen::gemm_dims(rng, 80);
            let (mut m, mut k, mut n) = (dm, dk, dn);
            match case % 4 {
                0 => m = 1,
                1 => n = (n / 32) * 32 + 1 + (n % 31), // n % 32 != 0
                2 => k = (k / 4) * 4 + 1 + (k % 3),    // k % 4 != 0
                _ => {}
            }
            let a: Vec<i8> = (0..m * k).map(|_| rng.next_u64() as i8).collect();
            let b: Vec<u8> = (0..k * n).map(|_| rng.next_u64() as u8).collect();
            let mut want = vec![0i32; m * n];
            igemm_with_threads(KernelChoice::Portable, 1, m, k, n, &a, &b, &mut want);
            let bp = PackedB::pack(&b, k, n);
            let mut apack = Vec::new();
            let mut c = vec![0i32; m * n];
            for &choice in &choices {
                for threads in [1usize, 2, 4] {
                    c.fill(-1);
                    igemm_with_threads(choice, threads, m, k, n, &a, &b, &mut c);
                    if c != want {
                        return Err(format!("{choice:?} t={threads} packed ({m},{k},{n})"));
                    }
                    c.fill(-1);
                    igemm_prepacked_scratch(choice, threads, m, k, &a, &bp, &mut c, &mut apack);
                    if c != want {
                        return Err(format!("{choice:?} t={threads} prepacked ({m},{k},{n})"));
                    }
                }
            }
            Ok(())
        });
    }

    /// Tall-skinny shapes (m >> n) take the row-stripe partition axis
    /// (`dispatch::plan_partition` -> `Partition::Rows`); row stripes
    /// must stay bit-identical to the single-threaded column path for
    /// every kernel tier, packed and prepacked alike.
    #[test]
    fn row_stripe_partition_matches_single_thread() {
        let mut choices = vec![KernelChoice::Portable];
        if super::super::dispatch::avx2_available() {
            choices.push(KernelChoice::Avx2);
        }
        if super::super::vnni::vnni_available() {
            choices.push(KernelChoice::Vnni);
        }
        // n < STRIPE_ALIGN so only one column stripe exists; m large
        // enough (and flops past the crossover) that plan_partition
        // flips to Rows when threads > 1.
        for &(m, k, n) in &[(256usize, 384usize, 24usize), (129, 100, 7), (64, 33, 3)] {
            let a: Vec<i8> = (0..m * k).map(|i| (i as i32 * 31 % 251 - 125) as i8).collect();
            let b: Vec<u8> = (0..k * n).map(|i| (i * 17 % 256) as u8).collect();
            let mut want = vec![0i32; m * n];
            igemm_with_threads(KernelChoice::Portable, 1, m, k, n, &a, &b, &mut want);
            let bp = PackedB::pack(&b, k, n);
            let mut apack = Vec::new();
            let mut c = vec![0i32; m * n];
            for &choice in &choices {
                for threads in [2usize, 4] {
                    c.fill(-1);
                    igemm_with_threads(choice, threads, m, k, n, &a, &b, &mut c);
                    assert_eq!(c, want, "{choice:?} t={threads} packed ({m},{k},{n})");
                    c.fill(-1);
                    igemm_prepacked_scratch(choice, threads, m, k, &a, &bp, &mut c, &mut apack);
                    assert_eq!(c, want, "{choice:?} t={threads} prepacked ({m},{k},{n})");
                }
            }
        }
    }

    #[test]
    fn corrected_equals_shifted_reference() {
        // igemm_corrected must equal sum (a - za)(b - 128) exactly
        let (m, k, n) = (3, 7, 5);
        let a: Vec<i8> = (0..m * k).map(|i| (i as i32 * 37 % 251 - 125) as i8).collect();
        let b: Vec<u8> = (0..k * n).map(|i| (i * 83 % 256) as u8).collect();
        for za in [0i32, 9, -5] {
            let mut c = vec![0i32; m * n];
            igemm_corrected(m, k, n, &a, za, &b, &mut c);
            for i in 0..m {
                for j in 0..n {
                    let mut expect = 0i32;
                    for p in 0..k {
                        expect += (a[i * k + p] as i32 - za)
                            * (b[p * n + j] as i32 - UINT8_ZERO_POINT);
                    }
                    assert_eq!(c[i * n + j], expect, "za={za} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn corrected_scratch_reuse_matches_fresh() {
        // one PackScratch across calls of different shapes and zero
        // points must match the allocating path exactly
        let mut ws = PackScratch::default();
        let mut rngstate = 0x5EEDu64;
        let mut next = move || {
            rngstate = rngstate.wrapping_mul(6364136223846793005).wrapping_add(1);
            (rngstate >> 33) as u8
        };
        for &(m, k, n, za) in &[(4, 9, 33, 7), (1, 16, 5, 0), (8, 64, 64, -3), (2, 3, 2, 0)] {
            let a: Vec<i8> = (0..m * k).map(|_| next() as i8).collect();
            let b: Vec<u8> = (0..k * n).map(|_| next()).collect();
            let mut c1 = vec![0i32; m * n];
            igemm_corrected_scratch(m, k, n, &a, za, &b, &mut c1, &mut ws);
            let mut c2 = vec![0i32; m * n];
            igemm_corrected(m, k, n, &a, za, &b, &mut c2);
            assert_eq!(c1, c2, "({m},{k},{n}) za={za}");
        }
    }

    #[test]
    fn quantize_s8_clips_and_rounds() {
        let src = vec![0.0, 0.26, -0.26, 100.0, -100.0, 0.24];
        let mut dst = vec![0i8; 6];
        quantize_s8(&src, 0.5, 0, &mut dst);
        assert_eq!(dst, vec![0, 1, -1, 127, -128, 0]);
    }

    #[test]
    fn quantize_u8_zero_point() {
        let src = vec![0.0, 0.5, -0.5, 1000.0, -1000.0];
        let mut dst = vec![0u8; 5];
        quantize_u8(&src, 0.5, &mut dst);
        assert_eq!(dst, vec![128, 129, 127, 255, 0]);
    }

    #[test]
    fn dequantize_roundtrip_error_within_half_step() {
        let scale = 0.02f32;
        let src: Vec<f32> = (-100..100).map(|i| i as f32 * 0.011).collect();
        let mut q = vec![0i8; src.len()];
        quantize_s8(&src, scale, 0, &mut q);
        let mut back = vec![0f32; src.len()];
        dequantize_s8(&q, scale, 0, &mut back);
        for (x, y) in src.iter().zip(&back) {
            if x.abs() < 127.0 * scale {
                assert!((x - y).abs() <= scale * 0.5 + 1e-6, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn dequantize_s8_tiers_bit_identical() {
        // the dispatching entry (AVX2 when available) must match the
        // plain scalar formula bit-for-bit for every length (tail
        // handling included), zero point, and scale — including odd
        // scales whose f32 product rounding the SIMD lane must replicate
        check("dequantize_s8 tier parity", 0xDE0A, 64, |rng, case| {
            let len = match case % 4 {
                0 => rng.range(1, 7) as usize, // below the SIMD width
                1 => 8,
                _ => rng.range(1, 300) as usize,
            };
            let src: Vec<i8> = (0..len).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
            let zero = rng.range(0, 20) as i32 - 10;
            let scale = (rng.f64() as f32) * 0.37 + 1e-4;
            let mut got = vec![0f32; len];
            dequantize_s8(&src, scale, zero, &mut got);
            for (i, (&g, &q)) in got.iter().zip(&src).enumerate() {
                let want = (q as i32 - zero) as f32 * scale;
                if g.to_bits() != want.to_bits() {
                    return Err(format!("lane {i}: {g} != {want} (len {len})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn scratch_reuse_no_stale_data() {
        let mut scratch = QGemmScratch::default();
        let a = vec![1.0f32; 4];
        let b = vec![1.0f32; 4];
        let mut out = vec![0.0f32; 4];
        quantized_matmul(2, 2, 2, &a, 0.01, 0, &b, 0.01, &mut out, &mut scratch);
        let first = out.clone();
        // second call with same inputs must give identical results
        quantized_matmul(2, 2, 2, &a, 0.01, 0, &b, 0.01, &mut out, &mut scratch);
        assert_eq!(first, out);
        // smaller problem after larger: buffers shrink logically
        let mut out1 = vec![0.0f32; 1];
        quantized_matmul(1, 1, 1, &[2.0], 0.1, 0, &[3.0], 0.1, &mut out1, &mut scratch);
        assert!((out1[0] - 6.0).abs() < 0.2);
    }
}
