//! Quantized GEMM: `s8 x u8 -> i32`, the software analogue of VNNI.
//!
//! Cascade Lake's `vpdpbusd` fuses 4 u8*s8 products + i32 add into one
//! instruction per lane; GEMMLOWP (what stock TensorFlow used) does the
//! same arithmetic scalar-by-scalar, which is why the paper swapped in
//! MKL's kernel.  Our inner loop mirrors the vpdpbusd dataflow — an
//! unrolled quad MAC over a k-packed B panel — which rustc lowers to
//! `pmaddubsw`/`pmaddwd`-style vector code on AVX2+ targets, and which
//! beats the f32 kernel on memory traffic 4:1 exactly as VNNI does.
//!
//! Entry points:
//! * [`igemm`]            — raw `A_s8 [m,k] * B_u8 [k,n] -> C_i32 [m,n]`
//! * [`igemm_corrected`]  — subtracts the zero-point corrections
//! * [`quantized_matmul`] — full f32 -> int8 -> f32 path matching
//!   `python/compile/kernels/ref.py::fake_quant_matmul_ref`

use super::UINT8_ZERO_POINT;

const MC: usize = 64;
const KC: usize = 256;
const NC: usize = 512;

/// Explicit kernel selector for [`igemm_with`].
///
/// [`use_vnni`] caches the `QUANTNMT_NO_VNNI` environment check in a
/// `OnceLock`, so a single test binary could never exercise *both*
/// kernels through [`igemm`].  Passing a `KernelChoice` bypasses the
/// cached dispatch entirely, letting parity tests force the portable
/// path and the VNNI path side by side in one process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelChoice {
    /// cached runtime dispatch: VNNI when available and not disabled,
    /// with the m >= 2 shape heuristic (what [`igemm`] does)
    Auto,
    /// force the portable blocked quad-MAC kernel
    Portable,
    /// force the AVX-512 VNNI kernel, even for m == 1 (panics when the
    /// CPU lacks VNNI — callers gate on [`super::vnni::vnni_available`])
    Vnni,
}

/// `c = a * b` with i32 accumulation (c fully overwritten).
///
/// Dispatches to the AVX-512 VNNI kernel when the CPU supports it
/// (packing B on the fly); otherwise runs the portable blocked
/// quad-MAC kernel.
pub fn igemm(m: usize, k: usize, n: usize, a: &[i8], b: &[u8], c: &mut [i32]) {
    igemm_with(KernelChoice::Auto, m, k, n, a, b, c);
}

/// [`igemm`] with an explicit kernel choice (see [`KernelChoice`]).
pub fn igemm_with(
    choice: KernelChoice,
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    b: &[u8],
    c: &mut [i32],
) {
    assert_eq!(a.len(), m * k, "a len");
    assert_eq!(b.len(), k * n, "b len");
    assert_eq!(c.len(), m * n, "c len");
    c.fill(0);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let vnni = match choice {
        KernelChoice::Portable => false,
        KernelChoice::Vnni => {
            assert!(
                super::vnni::vnni_available(),
                "KernelChoice::Vnni forced on a CPU without AVX-512 VNNI"
            );
            true
        }
        // Shape-aware kernel choice (§5.2): packing B costs one O(k*n)
        // pass, amortized over m output rows — below ~2 rows the
        // portable kernel wins (the paper likewise picks kernels by
        // matrix shape).
        KernelChoice::Auto => m >= 2 && use_vnni(),
    };
    if vnni {
        let bp = super::vnni::PackedB::pack(b, k, n);
        // SAFETY: feature presence checked above (use_vnni / assert).
        unsafe { super::vnni::igemm_vnni(m, k, a, &bp, c) };
        return;
    }
    igemm_portable(m, k, n, a, b, c);
}

/// `c = a * B_packed` against a pre-packed B (weights are packed once).
pub fn igemm_prepacked(m: usize, k: usize, a: &[i8], bp: &super::vnni::PackedB, c: &mut [i32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(c.len(), m * bp.n);
    c.fill(0);
    if m == 0 || k == 0 || bp.n == 0 {
        return;
    }
    debug_assert!(super::vnni::vnni_available());
    // SAFETY: feature presence asserted above; callers pack B only on
    // VNNI-capable paths.
    unsafe { super::vnni::igemm_vnni(m, k, a, bp, c) };
}

/// Cached VNNI availability.
pub fn use_vnni() -> bool {
    use std::sync::OnceLock;
    static AVAIL: OnceLock<bool> = OnceLock::new();
    *AVAIL.get_or_init(|| {
        std::env::var("QUANTNMT_NO_VNNI").is_err() && super::vnni::vnni_available()
    })
}

/// Portable blocked kernel (also the reference for the VNNI path).
pub fn igemm_portable(m: usize, k: usize, n: usize, a: &[i8], b: &[u8], c: &mut [i32]) {
    for jc in (0..n).step_by(NC) {
        let nb = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kb = KC.min(k - pc);
            for ic in (0..m).step_by(MC) {
                let mb = MC.min(m - ic);
                block(k, n, a, b, c, ic, pc, jc, mb, kb, nb);
            }
        }
    }
}

/// Register-tiled micro-kernel.
///
/// Output tiles of NR=32 i32 lanes (two zmm registers on AVX-512) are
/// accumulated in a stack tile across the whole k-block before touching
/// C — the same register-blocking MKL's VNNI kernel uses, with the
/// quad-MAC inner statement (4 byte products into an i32 lane) that
/// `vpdpbusd` hard-wires.
const NR: usize = 32;

#[inline]
#[allow(clippy::too_many_arguments)]
fn block(
    k: usize,
    n: usize,
    a: &[i8],
    b: &[u8],
    c: &mut [i32],
    ic: usize,
    pc: usize,
    jc: usize,
    mb: usize,
    kb: usize,
    nb: usize,
) {
    let mut j = 0;
    while j < nb {
        let nr = NR.min(nb - j);
        if nr == NR {
            for i in 0..mb {
                let r = ic + i;
                let arow = &a[r * k + pc..r * k + pc + kb];
                let mut acc = [0i32; NR];
                let mut p = 0;
                // quad-unrolled k loop: one "software vpdpbusd" per 4 rows
                while p + 4 <= kb {
                    let a0 = arow[p] as i32;
                    let a1 = arow[p + 1] as i32;
                    let a2 = arow[p + 2] as i32;
                    let a3 = arow[p + 3] as i32;
                    let b0 = &b[(pc + p) * n + jc + j..][..NR];
                    let b1 = &b[(pc + p + 1) * n + jc + j..][..NR];
                    let b2 = &b[(pc + p + 2) * n + jc + j..][..NR];
                    let b3 = &b[(pc + p + 3) * n + jc + j..][..NR];
                    for x in 0..NR {
                        acc[x] += a0 * b0[x] as i32
                            + a1 * b1[x] as i32
                            + a2 * b2[x] as i32
                            + a3 * b3[x] as i32;
                    }
                    p += 4;
                }
                while p < kb {
                    let av = arow[p] as i32;
                    let brow = &b[(pc + p) * n + jc + j..][..NR];
                    for x in 0..NR {
                        acc[x] += av * brow[x] as i32;
                    }
                    p += 1;
                }
                let crow = &mut c[r * n + jc + j..][..NR];
                for x in 0..NR {
                    crow[x] += acc[x];
                }
            }
        } else {
            // ragged right edge: plain quad-MAC into C
            for i in 0..mb {
                let r = ic + i;
                let arow = &a[r * k + pc..r * k + pc + kb];
                let crow = &mut c[r * n + jc + j..r * n + jc + j + nr];
                for (p, &av) in arow.iter().enumerate() {
                    let brow = &b[(pc + p) * n + jc + j..][..nr];
                    let av = av as i32;
                    for x in 0..nr {
                        crow[x] += av * brow[x] as i32;
                    }
                }
            }
        }
        j += nr;
    }
}

/// Zero-point-corrected int GEMM:
///
/// `out[m,n] = sum_k (a[m,k] - za) * (b[k,n] - 128)` computed as the raw
/// product minus row/col-sum corrections (one pass, no materialized
/// shifted operands):
///
/// `raw - 128*rowsum(a) - za*colsum(b) + k*za*128`
pub fn igemm_corrected(
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    za: i32,
    b: &[u8],
    c: &mut [i32],
) {
    igemm(m, k, n, a, b, c);
    // rowsum(a): [m]
    let mut rowsum = vec![0i32; m];
    for i in 0..m {
        let mut s = 0i32;
        for p in 0..k {
            s += a[i * k + p] as i32;
        }
        rowsum[i] = s;
    }
    // colsum(b): [n] — only needed when za != 0 (paper §4.2: symmetric
    // mode keeps the offset zero to use the faster kernel)
    let mut colsum = vec![0i32; 0];
    if za != 0 {
        colsum = vec![0i32; n];
        for p in 0..k {
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                colsum[j] += brow[j] as i32;
            }
        }
    }
    let kz = k as i32 * za * UINT8_ZERO_POINT;
    for i in 0..m {
        let corr_row = UINT8_ZERO_POINT * rowsum[i];
        let crow = &mut c[i * n..(i + 1) * n];
        if za == 0 {
            for cx in crow.iter_mut() {
                *cx -= corr_row;
            }
        } else {
            for (j, cx) in crow.iter_mut().enumerate() {
                *cx = *cx - corr_row - za * colsum[j] + kz;
            }
        }
    }
}

/// Reusable buffers for the quantize -> igemm -> dequantize path, so the
/// engine's hot loop performs no allocation (perf pass, EXPERIMENTS §Perf).
#[derive(Default)]
pub struct QGemmScratch {
    pub a_q: Vec<i8>,
    pub b_q: Vec<u8>,
    pub acc: Vec<i32>,
}

/// Full quantized MatMul: quantize A (s8, affine) and B (u8, zp 128),
/// multiply with i32 accumulation, dequantize to f32.
///
/// Matches `kernels/ref.py::fake_quant_matmul_ref` bit-for-bit in the
/// integer domain (float rounding of the final scale may differ in ulp).
#[allow(clippy::too_many_arguments)]
pub fn quantized_matmul(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    a_scale: f32,
    a_zero: i32,
    b: &[f32],
    b_scale: f32,
    out: &mut [f32],
    scratch: &mut QGemmScratch,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    scratch.a_q.resize(m * k, 0);
    scratch.b_q.resize(k * n, 0);
    scratch.acc.resize(m * n, 0);
    quantize_s8(a, a_scale, a_zero, &mut scratch.a_q);
    quantize_u8(b, b_scale, &mut scratch.b_q);
    igemm_corrected(m, k, n, &scratch.a_q, a_zero, &scratch.b_q, &mut scratch.acc);
    let s = a_scale * b_scale;
    for (o, &acc) in out.iter_mut().zip(scratch.acc.iter()) {
        *o = acc as f32 * s;
    }
}

/// Quantize f32 -> s8 (paper eq. 5): `clip(round(x/scale) + zero, -128, 127)`.
pub fn quantize_s8(src: &[f32], scale: f32, zero: i32, dst: &mut [i8]) {
    assert_eq!(src.len(), dst.len());
    let inv = 1.0 / scale;
    for (d, &x) in dst.iter_mut().zip(src) {
        let q = (x * inv).round() as i32 + zero;
        *d = q.clamp(-128, 127) as i8;
    }
}

/// Quantize f32 -> u8 with fixed zero point 128.
pub fn quantize_u8(src: &[f32], scale: f32, dst: &mut [u8]) {
    assert_eq!(src.len(), dst.len());
    let inv = 1.0 / scale;
    for (d, &x) in dst.iter_mut().zip(src) {
        let q = (x * inv).round() as i32 + UINT8_ZERO_POINT;
        *d = q.clamp(0, 255) as u8;
    }
}

/// Dequantize s8 -> f32 (paper eq. 6).
pub fn dequantize_s8(src: &[i8], scale: f32, zero: i32, dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    for (d, &q) in dst.iter_mut().zip(src) {
        *d = (q as i32 - zero) as f32 * scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, gen};

    #[test]
    fn kernel_choice_portable_forces_portable_path() {
        // works on every CPU: Portable and Auto must agree bit-for-bit
        let (m, k, n) = (3, 10, 33);
        let a: Vec<i8> = (0..m * k).map(|i| (i as i32 % 251 - 125) as i8).collect();
        let b: Vec<u8> = (0..k * n).map(|i| (i * 7 % 256) as u8).collect();
        let mut c_auto = vec![0i32; m * n];
        let mut c_port = vec![0i32; m * n];
        igemm(m, k, n, &a, &b, &mut c_auto);
        igemm_with(KernelChoice::Portable, m, k, n, &a, &b, &mut c_port);
        assert_eq!(c_auto, c_port);
    }

    /// VNNI (on-the-fly packed and prepacked) must equal the portable
    /// kernel *exactly* — integer math, so not "close", identical.
    /// Shapes deliberately sweep the kernel's edge regimes: m == 1
    /// (below the Auto heuristic), ragged n % 32 != 0 (partial NR tile
    /// / masked store) and k % 4 != 0 (padded A quad tail).
    #[test]
    fn prop_vnni_and_prepacked_match_portable_exactly() {
        if !super::super::vnni::vnni_available() {
            eprintln!("skipping: no AVX-512 VNNI");
            return;
        }
        check("vnni==portable", 0xAB12, 64, |rng, case| {
            let (dm, dk, dn) = gen::gemm_dims(rng, 80);
            let (mut m, mut k, mut n) = (dm, dk, dn);
            // force each edge regime on a rotating schedule (plus the
            // unconstrained random shapes on case % 4 == 3)
            match case % 4 {
                0 => m = 1,
                1 => n = (n / 32) * 32 + 1 + (n % 31), // n % 32 != 0
                2 => k = (k / 4) * 4 + 1 + (k % 3),    // k % 4 != 0
                _ => {}
            }
            let a: Vec<i8> = (0..m * k).map(|_| rng.next_u64() as i8).collect();
            let b: Vec<u8> = (0..k * n).map(|_| rng.next_u64() as u8).collect();
            let mut c_port = vec![0i32; m * n];
            igemm_with(KernelChoice::Portable, m, k, n, &a, &b, &mut c_port);
            let mut c_vnni = vec![0i32; m * n];
            igemm_with(KernelChoice::Vnni, m, k, n, &a, &b, &mut c_vnni);
            if c_vnni != c_port {
                return Err(format!("vnni != portable at ({m},{k},{n})"));
            }
            let bp = super::super::vnni::PackedB::pack(&b, k, n);
            let mut c_pre = vec![0i32; m * n];
            igemm_prepacked(m, k, &a, &bp, &mut c_pre);
            if c_pre != c_port {
                return Err(format!("prepacked != portable at ({m},{k},{n})"));
            }
            Ok(())
        });
    }

    #[test]
    fn corrected_equals_shifted_reference() {
        // igemm_corrected must equal sum (a - za)(b - 128) exactly
        let (m, k, n) = (3, 7, 5);
        let a: Vec<i8> = (0..m * k).map(|i| (i as i32 * 37 % 251 - 125) as i8).collect();
        let b: Vec<u8> = (0..k * n).map(|i| (i * 83 % 256) as u8).collect();
        for za in [0i32, 9, -5] {
            let mut c = vec![0i32; m * n];
            igemm_corrected(m, k, n, &a, za, &b, &mut c);
            for i in 0..m {
                for j in 0..n {
                    let mut expect = 0i32;
                    for p in 0..k {
                        expect += (a[i * k + p] as i32 - za)
                            * (b[p * n + j] as i32 - UINT8_ZERO_POINT);
                    }
                    assert_eq!(c[i * n + j], expect, "za={za} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn quantize_s8_clips_and_rounds() {
        let src = vec![0.0, 0.26, -0.26, 100.0, -100.0, 0.24];
        let mut dst = vec![0i8; 6];
        quantize_s8(&src, 0.5, 0, &mut dst);
        assert_eq!(dst, vec![0, 1, -1, 127, -128, 0]);
    }

    #[test]
    fn quantize_u8_zero_point() {
        let src = vec![0.0, 0.5, -0.5, 1000.0, -1000.0];
        let mut dst = vec![0u8; 5];
        quantize_u8(&src, 0.5, &mut dst);
        assert_eq!(dst, vec![128, 129, 127, 255, 0]);
    }

    #[test]
    fn dequantize_roundtrip_error_within_half_step() {
        let scale = 0.02f32;
        let src: Vec<f32> = (-100..100).map(|i| i as f32 * 0.011).collect();
        let mut q = vec![0i8; src.len()];
        quantize_s8(&src, scale, 0, &mut q);
        let mut back = vec![0f32; src.len()];
        dequantize_s8(&q, scale, 0, &mut back);
        for (x, y) in src.iter().zip(&back) {
            if x.abs() < 127.0 * scale {
                assert!((x - y).abs() <= scale * 0.5 + 1e-6, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn scratch_reuse_no_stale_data() {
        let mut scratch = QGemmScratch::default();
        let a = vec![1.0f32; 4];
        let b = vec![1.0f32; 4];
        let mut out = vec![0.0f32; 4];
        quantized_matmul(2, 2, 2, &a, 0.01, 0, &b, 0.01, &mut out, &mut scratch);
        let first = out.clone();
        // second call with same inputs must give identical results
        quantized_matmul(2, 2, 2, &a, 0.01, 0, &b, 0.01, &mut out, &mut scratch);
        assert_eq!(first, out);
        // smaller problem after larger: buffers shrink logically
        let mut out1 = vec![0.0f32; 1];
        quantized_matmul(1, 1, 1, &[2.0], 0.1, 0, &[3.0], 0.1, &mut out1, &mut scratch);
        assert!((out1[0] - 6.0).abs() < 0.2);
    }
}
