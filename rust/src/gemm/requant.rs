//! Fused requantize epilogues: i32 accumulator -> i8/u8 of the *next*
//! site, with no f32 tensor in between.
//!
//! The paper's INT8 pipeline (§4.1) pays a Dequantize after every GEMM
//! and a fresh QuantizeV2 before the next one.  When both sides of that
//! boundary are quantized the round-trip is pure overhead: the i32
//! accumulator already holds the product at a *known* scale
//! `sa * sb_j`, so mapping it onto the next site's grid is one
//! multiply-round per element:
//!
//! ```text
//! out_q = clamp(round(acc_corrected * M_j) + zp_out)
//! M_j   = (sa * sb_j) / s_out          (per output channel j)
//! ```
//!
//! `M_j` is precomputed per site in `CompiledPlan` (per-channel when the
//! weight uses per-channel B scales, a single entry otherwise).  Biases
//! fold into the accumulator as integers (`round(bias_j / (sa*sb_j))`)
//! and ReLU is exact in the integer domain (`max(acc, 0)`, since every
//! multiplier is positive) — so GEMM -> bias -> ReLU -> requantize is
//! one pass over the i32 tile.
//!
//! The epilogue itself is deterministic scalar math applied after the
//! tiled kernels, so `igemm_requant` output is bit-identical across
//! Portable/AVX2/VNNI and any thread count — exactly the parity
//! contract the raw accumulator already satisfies.  The GEMM inside
//! every entry point funnels through `igemm_scratch` /
//! `igemm_prepacked_scratch`, so fused calls ride the persistent
//! worker pool (`super::pool`) automatically; `tests/pool_parity.rs`
//! pins the pooled-vs-scoped parity of the fused path explicitly.

use super::igemm::{apply_zero_corrections, igemm_prepacked_scratch, igemm_scratch};
use super::pack::PackedB;
use super::{KernelChoice, PackScratch, UINT8_ZERO_POINT};

/// Per-site requantize epilogue, resolved at plan-build time.
///
/// `mult` holds the combined multiplier `(sa * sb_j) / s_out`: one entry
/// per output channel for per-channel weights, a single entry for
/// per-tensor scales.  `in_zero` is the zero point the i8 A operand was
/// quantized with (needed for the zero-point corrections), `out_zero`
/// the target grid's zero point (ignored by the u8 variant, which pins
/// it to 128 like every u8 operand in this crate).
#[derive(Debug, Clone, Default)]
pub struct RequantParams {
    /// Zero point of the incoming i8 activation operand.
    pub in_zero: i32,
    /// Combined multiplier per output channel (len `n`) or per tensor
    /// (len 1): `(a_scale * b_scale_j) / out_scale`.
    pub mult: Vec<f32>,
    /// Zero point of the output grid (i8 target; u8 targets use 128).
    pub out_zero: i32,
    /// Bias folded into accumulator units: `round(bias_j / (sa*sb_j))`.
    pub bias: Option<Vec<i32>>,
    /// Apply ReLU in the integer domain (after bias, before rescale).
    pub relu: bool,
}

impl RequantParams {
    /// Per-tensor epilogue with no bias / ReLU.
    pub fn per_tensor(in_zero: i32, mult: f32, out_zero: i32) -> Self {
        RequantParams {
            in_zero,
            mult: vec![mult],
            out_zero,
            bias: None,
            relu: false,
        }
    }

    #[inline]
    fn mult_at(&self, j: usize) -> f32 {
        if self.mult.len() == 1 {
            self.mult[0]
        } else {
            self.mult[j]
        }
    }

    /// The bias+ReLU+rescale core shared by every output flavor:
    /// corrected accumulator -> integer on the output grid (pre-clamp).
    #[inline]
    fn requant_one(&self, j: usize, acc: i32) -> i32 {
        let mut v = acc;
        if let Some(b) = &self.bias {
            v += b[j];
        }
        if self.relu {
            v = v.max(0);
        }
        (v as f32 * self.mult_at(j)).round() as i32
    }
}

/// Rescale a corrected i32 accumulator tile onto an i8 grid.
pub fn requant_epilogue_s8(rows: usize, n: usize, acc: &[i32], rp: &RequantParams, out: &mut [i8]) {
    assert_eq!(acc.len(), rows * n, "requant acc len");
    assert_eq!(out.len(), rows * n, "requant out len");
    if rp.mult.len() != 1 {
        assert_eq!(rp.mult.len(), n, "requant mult len");
    }
    for i in 0..rows {
        let arow = &acc[i * n..(i + 1) * n];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, (o, &a)) in orow.iter_mut().zip(arow).enumerate() {
            let q = rp.requant_one(j, a) + rp.out_zero;
            *o = q.clamp(-128, 127) as i8;
        }
    }
}

/// Rescale a corrected i32 accumulator tile onto the u8 grid (zero
/// point fixed at 128): the B-side operand of the next dynamic GEMM or
/// a u8 KV-cache row.
pub fn requant_epilogue_u8(rows: usize, n: usize, acc: &[i32], rp: &RequantParams, out: &mut [u8]) {
    assert_eq!(acc.len(), rows * n, "requant acc len");
    assert_eq!(out.len(), rows * n, "requant out len");
    if rp.mult.len() != 1 {
        assert_eq!(rp.mult.len(), n, "requant mult len");
    }
    for i in 0..rows {
        let arow = &acc[i * n..(i + 1) * n];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, (o, &a)) in orow.iter_mut().zip(arow).enumerate() {
            let q = rp.requant_one(j, a) + UINT8_ZERO_POINT;
            *o = q.clamp(0, 255) as u8;
        }
    }
}

/// Rescale a corrected i32 accumulator into *another integer domain*
/// (the residual stream at the layer's activation scale), adding the
/// i8 residual input on the way: `out = round(acc_j * mult_j) + bias_j
/// + (x_q - x_zero)`.  The result stays i32 so integer LayerNorm can
/// consume it without an i8 round-trip in the middle of the residual.
pub fn requant_epilogue_residual(
    rows: usize,
    n: usize,
    acc: &[i32],
    rp: &RequantParams,
    x_q: &[i8],
    out: &mut [i32],
) {
    assert_eq!(acc.len(), rows * n, "requant acc len");
    assert_eq!(x_q.len(), rows * n, "requant residual len");
    assert_eq!(out.len(), rows * n, "requant out len");
    if rp.mult.len() != 1 {
        assert_eq!(rp.mult.len(), n, "requant mult len");
    }
    for i in 0..rows {
        let arow = &acc[i * n..(i + 1) * n];
        let xrow = &x_q[i * n..(i + 1) * n];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, ((o, &a), &x)) in orow.iter_mut().zip(arow).zip(xrow).enumerate() {
            *o = rp.requant_one(j, a) + (x as i32 - rp.in_zero);
        }
    }
}

/// Compute the corrected accumulator `sum (a - za)(b - 128)` for an
/// unpacked u8 B, sharing `ws` for panels and colsum.  Factored out so
/// the s8/u8 fused entry points stay thin.
#[allow(clippy::too_many_arguments)]
fn corrected_acc(
    choice: KernelChoice,
    threads: usize,
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    za: i32,
    b: &[u8],
    acc: &mut Vec<i32>,
    ws: &mut PackScratch,
) {
    acc.resize(m * n, 0);
    igemm_scratch(choice, threads, m, k, n, a, b, acc, ws);
    ws.colsum.clear();
    if za != 0 {
        ws.colsum.resize(n, 0);
        for p in 0..k {
            let brow = &b[p * n..(p + 1) * n];
            for (s, &bx) in ws.colsum.iter_mut().zip(brow) {
                *s += bx as i32;
            }
        }
    }
    apply_zero_corrections(m, k, n, a, za, &ws.colsum, acc);
}

/// Fused `igemm` + requantize: `out_s8 = requant(sum (a - za)(b - 128))`
/// — the i32 accumulator never surfaces as f32.  `acc` is caller-owned
/// scratch (the engine reuses its `QGemmScratch::acc`).
#[allow(clippy::too_many_arguments)]
pub fn igemm_requant_s8(
    choice: KernelChoice,
    threads: usize,
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    b: &[u8],
    rp: &RequantParams,
    out: &mut [i8],
    acc: &mut Vec<i32>,
    ws: &mut PackScratch,
) {
    corrected_acc(choice, threads, m, k, n, a, rp.in_zero, b, acc, ws);
    requant_epilogue_s8(m, n, acc, rp, out);
}

/// [`igemm_requant_s8`] emitting onto the u8 grid (zero point 128).
#[allow(clippy::too_many_arguments)]
pub fn igemm_requant_u8(
    choice: KernelChoice,
    threads: usize,
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    b: &[u8],
    rp: &RequantParams,
    out: &mut [u8],
    acc: &mut Vec<i32>,
    ws: &mut PackScratch,
) {
    corrected_acc(choice, threads, m, k, n, a, rp.in_zero, b, acc, ws);
    requant_epilogue_u8(m, n, acc, rp, out);
}

/// Fused requantize against a pre-packed weight panel (the hot path for
/// every projection): the weight's precomputed `colsum` supplies the
/// zero-point correction, `a_pack` is the caller-owned A panel.
#[allow(clippy::too_many_arguments)]
pub fn igemm_requant_prepacked_s8(
    choice: KernelChoice,
    threads: usize,
    m: usize,
    k: usize,
    a: &[i8],
    bp: &PackedB,
    colsum: &[i32],
    rp: &RequantParams,
    out: &mut [i8],
    acc: &mut Vec<i32>,
    a_pack: &mut Vec<i32>,
) {
    let n = bp.n;
    acc.resize(m * n, 0);
    igemm_prepacked_scratch(choice, threads, m, k, a, bp, acc, a_pack);
    apply_zero_corrections(m, k, n, a, rp.in_zero, colsum, acc);
    requant_epilogue_s8(m, n, acc, rp, out);
}

/// [`igemm_requant_prepacked_s8`] emitting onto the u8 grid.
#[allow(clippy::too_many_arguments)]
pub fn igemm_requant_prepacked_u8(
    choice: KernelChoice,
    threads: usize,
    m: usize,
    k: usize,
    a: &[i8],
    bp: &PackedB,
    colsum: &[i32],
    rp: &RequantParams,
    out: &mut [u8],
    acc: &mut Vec<i32>,
    a_pack: &mut Vec<i32>,
) {
    let n = bp.n;
    acc.resize(m * n, 0);
    igemm_prepacked_scratch(choice, threads, m, k, a, bp, acc, a_pack);
    apply_zero_corrections(m, k, n, a, rp.in_zero, colsum, acc);
    requant_epilogue_u8(m, n, acc, rp, out);
}

#[cfg(test)]
mod tests {
    use super::super::dispatch::{avx2_available, detect_isa, IsaLevel};
    use super::*;
    use crate::util::prop::{check, gen};
    use crate::util::rng::SplitMix64;

    /// Naive reference for the full fused contract: corrected product,
    /// bias in accumulator units, integer ReLU, rescale, clamp.
    fn requant_ref_s8(
        m: usize,
        k: usize,
        n: usize,
        a: &[i8],
        b: &[u8],
        rp: &RequantParams,
    ) -> Vec<i8> {
        let mut out = vec![0i8; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i64;
                for p in 0..k {
                    acc += (a[i * k + p] as i64 - rp.in_zero as i64)
                        * (b[p * n + j] as i64 - UINT8_ZERO_POINT as i64);
                }
                let mut v = acc as i32;
                if let Some(bias) = &rp.bias {
                    v += bias[j];
                }
                if rp.relu {
                    v = v.max(0);
                }
                let q = (v as f32 * rp.mult_at(j)).round() as i32 + rp.out_zero;
                out[i * n + j] = q.clamp(-128, 127) as i8;
            }
        }
        out
    }

    fn rand_operands(rng: &mut SplitMix64, m: usize, k: usize, n: usize) -> (Vec<i8>, Vec<u8>) {
        let a: Vec<i8> = (0..m * k)
            .map(|_| (rng.below(256) as i32 - 128) as i8)
            .collect();
        let b: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
        (a, b)
    }

    /// Kernel choices runnable on this host (Auto included so the
    /// resolved default is always in the parity set).
    fn host_choices() -> Vec<KernelChoice> {
        let mut v = vec![KernelChoice::Auto, KernelChoice::Portable];
        if avx2_available() {
            v.push(KernelChoice::Avx2);
        }
        if detect_isa() == IsaLevel::Avx512Vnni {
            v.push(KernelChoice::Vnni);
        }
        v
    }

    /// Rotating epilogue flavors: per-tensor/per-channel multiplier,
    /// bias on/off, ReLU on/off, affine/symmetric input zero.
    fn case_params(rng: &mut SplitMix64, case: usize, n: usize) -> RequantParams {
        let in_zero = if case % 2 == 0 { 0 } else { rng.range(1, 11) as i32 - 6 };
        let mult = if case % 3 == 0 {
            vec![0.002 + rng.f64() as f32 * 0.01]
        } else {
            (0..n).map(|_| 0.002 + rng.f64() as f32 * 0.01).collect()
        };
        let bias = if case % 4 < 2 {
            Some((0..n).map(|_| rng.range(0, 4000) as i32 - 2000).collect())
        } else {
            None
        };
        RequantParams {
            in_zero,
            mult,
            out_zero: rng.range(0, 9) as i32 - 4,
            bias,
            relu: case % 5 == 0,
        }
    }

    #[test]
    fn fused_s8_matches_reference_across_kernels_and_threads() {
        check("igemm_requant_s8 parity", 0xF05E, 48, |rng, case| {
            let (m, k, n) = gen::gemm_dims(rng, 48);
            // rotate in the stripe/tail edge shapes
            let (m, n) = match case % 4 {
                0 => (1, n),
                1 => (m, 33),
                _ => (m, n),
            };
            let (a, b) = rand_operands(rng, m, k, n);
            let rp = case_params(rng, case, n);
            let want = requant_ref_s8(m, k, n, &a, &b, &rp);
            for choice in host_choices() {
                for threads in [1usize, 2, 4] {
                    let mut out = vec![0i8; m * n];
                    let mut acc = Vec::new();
                    let mut ws = PackScratch::default();
                    igemm_requant_s8(
                        choice, threads, m, k, n, &a, &b, &rp, &mut out, &mut acc, &mut ws,
                    );
                    if out != want {
                        return Err(format!(
                            "mismatch {choice:?} x{threads} (m={m} k={k} n={n})"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fused_u8_matches_s8_shifted_grid() {
        // the u8 epilogue is the s8 one with zero pinned to 128: check
        // it against the reference formula directly
        check("igemm_requant_u8 parity", 0xF05F, 32, |rng, case| {
            let (m, k, n) = gen::gemm_dims(rng, 40);
            let (a, b) = rand_operands(rng, m, k, n);
            let mut rp = case_params(rng, case, n);
            rp.relu = false;
            let mut out = vec![0u8; m * n];
            let mut acc = Vec::new();
            let mut ws = PackScratch::default();
            igemm_requant_u8(
                KernelChoice::Auto,
                1,
                m,
                k,
                n,
                &a,
                &b,
                &rp,
                &mut out,
                &mut acc,
                &mut ws,
            );
            for i in 0..m {
                for j in 0..n {
                    let mut accr = 0i64;
                    for p in 0..k {
                        accr += (a[i * k + p] as i64 - rp.in_zero as i64)
                            * (b[p * n + j] as i64 - 128);
                    }
                    let mut v = accr as i32;
                    if let Some(bias) = &rp.bias {
                        v += bias[j];
                    }
                    let q = (v as f32 * rp.mult_at(j)).round() as i32 + UINT8_ZERO_POINT;
                    if out[i * n + j] != q.clamp(0, 255) as u8 {
                        return Err(format!("u8 mismatch at ({i},{j})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prepacked_matches_unpacked() {
        check("igemm_requant prepacked parity", 0xF060, 32, |rng, case| {
            let (m, k, n) = gen::gemm_dims(rng, 48);
            let (a, b) = rand_operands(rng, m, k, n);
            let rp = case_params(rng, case, n);
            let mut want = vec![0i8; m * n];
            let mut acc = Vec::new();
            let mut ws = PackScratch::default();
            igemm_requant_s8(
                KernelChoice::Auto,
                1,
                m,
                k,
                n,
                &a,
                &b,
                &rp,
                &mut want,
                &mut acc,
                &mut ws,
            );
            let bp = PackedB::pack(&b, k, n);
            let mut colsum = vec![0i32; n];
            for p in 0..k {
                for j in 0..n {
                    colsum[j] += b[p * n + j] as i32;
                }
            }
            for threads in [1usize, 2, 4] {
                let mut out = vec![0i8; m * n];
                let mut a_pack = Vec::new();
                igemm_requant_prepacked_s8(
                    KernelChoice::Auto,
                    threads,
                    m,
                    k,
                    &a,
                    &bp,
                    &colsum,
                    &rp,
                    &mut out,
                    &mut acc,
                    &mut a_pack,
                );
                if out != want {
                    return Err(format!("prepacked mismatch x{threads}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn residual_epilogue_adds_centered_input() {
        let (m, n) = (2usize, 3usize);
        let acc = vec![100, -200, 300, 50, 0, -50];
        let x_q: Vec<i8> = vec![10, -10, 0, 5, 5, 5];
        let rp = RequantParams::per_tensor(2, 0.5, 0);
        let mut out = vec![0i32; m * n];
        requant_epilogue_residual(m, n, &acc, &rp, &x_q, &mut out);
        for idx in 0..m * n {
            let want = (acc[idx] as f32 * 0.5).round() as i32 + (x_q[idx] as i32 - 2);
            assert_eq!(out[idx], want, "idx {idx}");
        }
    }

    #[test]
    fn relu_is_exact_in_integer_domain() {
        // relu(acc) then rescale must equal rescale-then-relu on the
        // dequantized value, because every multiplier is positive
        let rp = RequantParams {
            in_zero: 0,
            mult: vec![0.01],
            out_zero: 0,
            bias: Some(vec![-500]),
            relu: true,
        };
        let acc = vec![400i32, 600, 1500]; // biased: -100, 100, 1000
        let mut out = vec![0i8; 3];
        requant_epilogue_s8(1, 3, &acc, &rp, &mut out);
        assert_eq!(out, vec![0i8, 1, 10]);
    }
}
