//! Blocked FP32 GEMM — the paper's "AVX-512 FP32 MatMul" baseline.
//!
//! Row-major `C[m,n] = A[m,k] * B[k,n]`.  Strategy:
//!
//! * L2-sized blocking over (M, K, N);
//! * within a block, a 4-row micro-kernel walks B rows sequentially
//!   (unit stride) and keeps 4 running C rows in registers — rustc
//!   auto-vectorizes the inner `n` loop into AVX FMAs;
//! * `C` is accumulated in place, so callers must zero it (the public
//!   entry points do);
//! * [`sgemm_threads`] fans the macro-loop out over disjoint output
//!   stripes — columns by default, rows for tall-skinny shapes
//!   (`dispatch::plan_partition`).  Each C element's k-summation order
//!   (K blocks ascending, rows within a block ascending) never depends
//!   on the partition axis, so even in f32 the result is bit-identical
//!   for every thread count, and for the pooled vs scoped dispatch
//!   paths alike.

use super::dispatch::{plan_partition, run_cols, run_rows, Partition, SendPtr};

const MC: usize = 64; // rows of A per block
const KC: usize = 256; // depth per block
const NC: usize = 512; // cols of B per block

/// `c = a * b` (c fully overwritten).
pub fn sgemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    sgemm_threads(1, m, k, n, a, b, c);
}

/// [`sgemm`] with an explicit worker count (`0` = the process default,
/// gated by the flops threshold; see `gemm::gemm_threads`).
pub fn sgemm_threads(
    threads: usize,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "a len");
    assert_eq!(b.len(), k * n, "b len");
    assert_eq!(c.len(), m * n, "c len");
    c.fill(0.0);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let cp = SendPtr(c.as_mut_ptr());
    match plan_partition(threads, m, k, n) {
        Partition::Cols(t) => run_cols(t, n, |j0, j1| {
            // SAFETY: stripes write disjoint columns of c.
            unsafe { sgemm_cols(m, k, n, a, b, cp.0, j0, j1) }
        }),
        Partition::Rows(t) => run_rows(t, m, |i0, i1| {
            // SAFETY: stripes write disjoint rows of c.
            unsafe { sgemm_rows(k, n, a, b, cp.0, i0, i1) }
        }),
    }
}

/// Blocked macro-loop restricted to output columns `[j0, j1)`.
///
/// # Safety
/// `cbase` must point at an `m * n` f32 buffer; concurrent callers must
/// write disjoint `[j0, j1)` ranges.
unsafe fn sgemm_cols(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    cbase: *mut f32,
    j0: usize,
    j1: usize,
) {
    let mut jc = j0;
    while jc < j1 {
        let nb = NC.min(j1 - jc);
        for pc in (0..k).step_by(KC) {
            let kb = KC.min(k - pc);
            for ic in (0..m).step_by(MC) {
                let mb = MC.min(m - ic);
                block(k, n, a, b, cbase, ic, pc, jc, mb, kb, nb);
            }
        }
        jc += nb;
    }
}

/// Row-stripe twin of [`sgemm_cols`]: rows `[i0, i1)` over the full
/// column range, for tall-skinny shapes (`dispatch::run_rows`).  The
/// k-block order seen by any element is the same as in [`sgemm_cols`]
/// (`pc` ascending, rows within a block ascending), so row partitions
/// are bit-identical to the single-range call even in f32.
///
/// # Safety
/// `cbase` must point at an `m * n` f32 buffer; concurrent callers must
/// write disjoint `[i0, i1)` row ranges.
unsafe fn sgemm_rows(
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    cbase: *mut f32,
    i0: usize,
    i1: usize,
) {
    let mut jc = 0;
    while jc < n {
        let nb = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kb = KC.min(k - pc);
            let mut ic = i0;
            while ic < i1 {
                let mb = MC.min(i1 - ic);
                block(k, n, a, b, cbase, ic, pc, jc, mb, kb, nb);
                ic += mb;
            }
        }
        jc += nb;
    }
}

#[inline]
#[allow(clippy::too_many_arguments)]
unsafe fn block(
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    cbase: *mut f32,
    ic: usize,
    pc: usize,
    jc: usize,
    mb: usize,
    kb: usize,
    nb: usize,
) {
    // SAFETY (both loops): rows are disjoint and [jc, jc+nb) is within
    // this caller's column stripe.
    let mut i = 0;
    // 4-row micro-kernel
    while i + 4 <= mb {
        let (r0, r1, r2, r3) = (ic + i, ic + i + 1, ic + i + 2, ic + i + 3);
        for p in 0..kb {
            let bp = &b[(pc + p) * n + jc..(pc + p) * n + jc + nb];
            let a0 = a[r0 * k + pc + p];
            let a1 = a[r1 * k + pc + p];
            let a2 = a[r2 * k + pc + p];
            let a3 = a[r3 * k + pc + p];
            // process rows one at a time, relying on the optimizer to
            // keep bp in registers/L1
            let c0 = std::slice::from_raw_parts_mut(cbase.add(r0 * n + jc), nb);
            for (cx, &bx) in c0.iter_mut().zip(bp) {
                *cx += a0 * bx;
            }
            let c1 = std::slice::from_raw_parts_mut(cbase.add(r1 * n + jc), nb);
            for (cx, &bx) in c1.iter_mut().zip(bp) {
                *cx += a1 * bx;
            }
            let c2 = std::slice::from_raw_parts_mut(cbase.add(r2 * n + jc), nb);
            for (cx, &bx) in c2.iter_mut().zip(bp) {
                *cx += a2 * bx;
            }
            let c3 = std::slice::from_raw_parts_mut(cbase.add(r3 * n + jc), nb);
            for (cx, &bx) in c3.iter_mut().zip(bp) {
                *cx += a3 * bx;
            }
        }
        i += 4;
    }
    // remainder rows
    while i < mb {
        let r = ic + i;
        for p in 0..kb {
            let av = a[r * k + pc + p];
            let bp = &b[(pc + p) * n + jc..(pc + p) * n + jc + nb];
            let cr = std::slice::from_raw_parts_mut(cbase.add(r * n + jc), nb);
            for (cx, &bx) in cr.iter_mut().zip(bp) {
                *cx += av * bx;
            }
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity() {
        let n = 8;
        let mut b = vec![0.0f32; n * n];
        for i in 0..n {
            b[i * n + i] = 1.0;
        }
        let a: Vec<f32> = (0..n * n).map(|i| i as f32).collect();
        let mut c = vec![0.0f32; n * n];
        sgemm(n, n, n, &a, &b, &mut c);
        assert_eq!(a, c);
    }

    #[test]
    fn known_product() {
        // [1 2; 3 4] * [5 6; 7 8] = [19 22; 43 50]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        let mut c = vec![0.0; 4];
        sgemm(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn non_multiple_of_block_dims() {
        // exercise remainder paths (m=5 -> one 4-row block + 1 remainder)
        let (m, k, n) = (5, 3, 2);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32).collect();
        let mut c = vec![0.0; m * n];
        let mut expect = vec![0.0; m * n];
        sgemm(m, k, n, &a, &b, &mut c);
        super::super::matmul_naive(m, k, n, &a, &b, &mut expect);
        assert_eq!(c, expect);
    }

    #[test]
    fn overwrites_stale_c() {
        let a = vec![1.0];
        let b = vec![2.0];
        let mut c = vec![99.0];
        sgemm(1, 1, 1, &a, &b, &mut c);
        assert_eq!(c, vec![2.0]);
    }

    /// f32 threading must be *bit*-identical, not approximately equal:
    /// stripes only change which columns a worker owns, never any
    /// element's k-summation order.
    #[test]
    fn prop_threaded_sgemm_bit_identical() {
        use crate::util::prop::{check, gen};
        check("sgemm threaded==single", 0xF32F, 32, |rng, case| {
            let (dm, dk, dn) = gen::gemm_dims(rng, 90);
            let (mut m, k, mut n) = (dm, dk, dn);
            if case % 3 == 0 {
                n = (n / 32) * 32 + 1 + (n % 31); // straddle a stripe edge
            } else if case % 3 == 1 {
                // tall-skinny: force the row-stripe partition axis
                m = m * 4 + 64;
                n = (n % 24) + 1;
            }
            let mut a = vec![0.0f32; m * k];
            let mut b = vec![0.0f32; k * n];
            rng.fill_uniform_f32(&mut a, 2.0);
            rng.fill_uniform_f32(&mut b, 2.0);
            let mut c1 = vec![0.0f32; m * n];
            sgemm_threads(1, m, k, n, &a, &b, &mut c1);
            for threads in [2usize, 4] {
                let mut ct = vec![0.0f32; m * n];
                sgemm_threads(threads, m, k, n, &a, &b, &mut ct);
                if c1.iter().zip(&ct).any(|(x, y)| x.to_bits() != y.to_bits()) {
                    return Err(format!("t={threads} not bit-identical at ({m},{k},{n})"));
                }
            }
            Ok(())
        });
    }
}
