//! The k/4-packed B panel shared by every packed kernel tier.
//!
//! `vpdpbusd` (and our AVX2 `pmaddwd` emulation of it) consumes, per
//! i32 lane, 4 consecutive k-bytes of one B column — so B is repacked
//! once so that each lane's quad is contiguous:
//! `bp[p/4][j][q] = b[(p+q)*n + j]` with geometry `kp = ceil(k/4)`
//! quads by `np = ceil(n/16)*16` padded lanes (layout `[kp][np][4]`
//! bytes).  Zero padding is neutral: zero u8 bytes contribute 0 to
//! every product *before* the zero-point correction, which uses the
//! true `k`/`n`.
//!
//! The same panel feeds all three tiers (AVX-512 VNNI, AVX2, and the
//! scalar packed fallback), so weight panels packed at plan-compile
//! time stay valid whatever `QUANTNMT_ISA` caps dispatch to later, and
//! activation-side panels can live in `QGemmScratch` and be re-packed
//! in place every call ([`PackedB::pack_into`]) without allocating.

/// Lanes per `vpdpbusd` (16 i32 lanes in a zmm).  The panel pads `n`
/// to this multiple so the 16-lane AVX-512 and 8-lane AVX2 kernels can
/// both load full vectors.
pub const VNNI_LANES: usize = 16;

/// Packed-B buffer (see module docs for the layout).
#[derive(Default)]
pub struct PackedB {
    pub data: Vec<u8>,
    pub k: usize,
    pub n: usize,
    pub kp: usize,
    pub np: usize,
}

impl PackedB {
    /// Pack row-major `b [k, n]` into a fresh panel.
    pub fn pack(b: &[u8], k: usize, n: usize) -> PackedB {
        let mut bp = PackedB::default();
        bp.pack_into(b, k, n);
        bp
    }

    /// Re-pack into this buffer, reusing its allocation (activation-side
    /// operands repack every call; see `QGemmScratch`).
    pub fn pack_into(&mut self, b: &[u8], k: usize, n: usize) {
        assert_eq!(b.len(), k * n);
        let kp = k.div_ceil(4);
        let np = n.div_ceil(VNNI_LANES) * VNNI_LANES;
        self.k = k;
        self.n = n;
        self.kp = kp;
        self.np = np;
        self.data.clear();
        self.data.resize(kp * np * 4, 0);
        for p in 0..k {
            let quad = p / 4;
            let q = p % 4;
            let brow = &b[p * n..(p + 1) * n];
            let dst = &mut self.data[quad * np * 4..(quad + 1) * np * 4];
            for (j, &bx) in brow.iter().enumerate() {
                dst[j * 4 + q] = bx;
            }
        }
    }

    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// Portable kernel over the packed layout: lets prepacked weight panels
/// run on the scalar tier (e.g. `QUANTNMT_ISA=scalar`, or the
/// Portable x prepacked cell of the parity cross product) and doubles
/// as the reference for the SIMD packed kernels.  Accumulates into a
/// pre-zeroed C over columns `[j0, j1)`.
///
/// # Safety
/// `cbase` must point at an `m * bp.n` i32 buffer; concurrent callers
/// must write disjoint `[j0, j1)` ranges (`dispatch::run_cols`).
pub(crate) unsafe fn igemm_packed_scalar(
    m: usize,
    k: usize,
    a: &[i8],
    bp: &PackedB,
    cbase: *mut i32,
    j0: usize,
    j1: usize,
) {
    packed_scalar_rect(m, k, a, bp, cbase, 0, m, j0, j1)
}

/// Row-stripe twin of [`igemm_packed_scalar`]: rows `[i0, i1)` over the
/// full column range, for tall-skinny shapes (`dispatch::run_rows`).
/// Rows are fully independent here, so any row partition is trivially
/// bit-identical to the single-range call.
///
/// # Safety
/// As [`igemm_packed_scalar`], with concurrent callers writing disjoint
/// `[i0, i1)` row ranges instead.
pub(crate) unsafe fn igemm_packed_scalar_rows(
    m: usize,
    k: usize,
    a: &[i8],
    bp: &PackedB,
    cbase: *mut i32,
    i0: usize,
    i1: usize,
) {
    packed_scalar_rect(m, k, a, bp, cbase, i0, i1, 0, bp.n)
}

/// Shared loop over the `[i0, i1) x [j0, j1)` output rectangle.
#[allow(clippy::too_many_arguments)]
unsafe fn packed_scalar_rect(
    m: usize,
    k: usize,
    a: &[i8],
    bp: &PackedB,
    cbase: *mut i32,
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
) {
    let n = bp.n;
    let np = bp.np;
    debug_assert_eq!(a.len(), m * k);
    debug_assert!(i1 <= m);
    debug_assert!(j1 <= n);
    for i in i0..i1 {
        let arow = &a[i * k..(i + 1) * k];
        // SAFETY: rows are disjoint and [j0, j1) is this worker's stripe.
        let crow = std::slice::from_raw_parts_mut(cbase.add(i * n + j0), j1 - j0);
        for quad in 0..bp.kp {
            let base = quad * 4;
            let take = (k - base).min(4);
            let mut aq = [0i32; 4];
            for (x, &av) in aq.iter_mut().zip(&arow[base..base + take]) {
                *x = av as i32;
            }
            let panel = &bp.data[quad * np * 4..];
            for (jj, cx) in crow.iter_mut().enumerate() {
                let d = &panel[(j0 + jj) * 4..(j0 + jj) * 4 + 4];
                *cx += aq[0] * d[0] as i32
                    + aq[1] * d[1] as i32
                    + aq[2] * d[2] as i32
                    + aq[3] * d[3] as i32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_layout_roundtrip() {
        let k = 6;
        let n = 3;
        let b: Vec<u8> = (0..k * n).map(|x| x as u8).collect();
        let bp = PackedB::pack(&b, k, n);
        assert_eq!(bp.kp, 2);
        assert_eq!(bp.np, 16);
        // element b[p, j] must live at data[(p/4)*np*4 + j*4 + p%4]
        for p in 0..k {
            for j in 0..n {
                assert_eq!(
                    bp.data[(p / 4) * bp.np * 4 + j * 4 + p % 4],
                    b[p * n + j],
                    "(p={p}, j={j})"
                );
            }
        }
    }

    #[test]
    fn pack_into_reuses_and_rewrites() {
        let mut bp = PackedB::default();
        let b1: Vec<u8> = (0..8 * 20).map(|x| (x % 251) as u8).collect();
        bp.pack_into(&b1, 8, 20);
        let first_len = bp.data.len();
        // smaller re-pack must fully overwrite (incl. padding back to 0)
        let b2: Vec<u8> = (0..5 * 3).map(|x| (x + 1) as u8).collect();
        bp.pack_into(&b2, 5, 3);
        assert_eq!(bp.k, 5);
        assert_eq!(bp.n, 3);
        assert_eq!(bp.np, 16);
        // the allocation is reused, not shrunk
        assert!(bp.data.capacity() >= first_len);
        let fresh = PackedB::pack(&b2, 5, 3);
        assert_eq!(bp.data, fresh.data);
    }

    #[test]
    fn packed_scalar_rows_match_cols() {
        let (m, k, n) = (11, 10, 21);
        let a: Vec<i8> = (0..m * k).map(|i| (i as i32 * 7 % 251 - 125) as i8).collect();
        let b: Vec<u8> = (0..k * n).map(|i| (i * 13 % 256) as u8).collect();
        let bp = PackedB::pack(&b, k, n);
        let mut want = vec![0i32; m * n];
        unsafe { igemm_packed_scalar(m, k, &a, &bp, want.as_mut_ptr(), 0, n) };
        let mut c = vec![0i32; m * n];
        for (i0, i1) in [(0usize, 4usize), (4, 9), (9, 11)] {
            unsafe { igemm_packed_scalar_rows(m, k, &a, &bp, c.as_mut_ptr(), i0, i1) };
        }
        assert_eq!(c, want);
    }

    #[test]
    fn packed_scalar_matches_naive() {
        let (m, k, n) = (3, 10, 21);
        let a: Vec<i8> = (0..m * k).map(|i| (i as i32 * 7 % 251 - 125) as i8).collect();
        let b: Vec<u8> = (0..k * n).map(|i| (i * 13 % 256) as u8).collect();
        let bp = PackedB::pack(&b, k, n);
        let mut c = vec![0i32; m * n];
        // run in two stripes to exercise the column-range path
        unsafe {
            igemm_packed_scalar(m, k, &a, &bp, c.as_mut_ptr(), 0, 16);
            igemm_packed_scalar(m, k, &a, &bp, c.as_mut_ptr(), 16, n);
        }
        let mut want = vec![0i32; m * n];
        crate::gemm::igemm_naive(m, k, n, &a, &b, &mut want);
        assert_eq!(c, want);
    }
}
