//! Persistent GEMM worker pool: amortized parallel dispatch.
//!
//! The scoped path (`dispatch::run_striped`'s fallback) re-spawns OS
//! threads on every parallel GEMM call; the old `PAR_FLOPS_MIN` gate
//! documents the consequence — spawn+join costs more than the GEMM
//! below ~4M flops, so decode-shape calls (m = active slots) never
//! went parallel.  This module keeps one process-wide team of workers
//! alive instead, so fanning a macro-loop out costs a few atomic
//! operations (plus an unpark when a worker has gone idle), and the
//! crossover drops by ~32x (`dispatch::PAR_FLOPS_MIN_POOLED`).
//!
//! # Protocol
//!
//! One job slot lives in [`Shared`]; callers serialize on a submit
//! mutex (never blocking: a contended caller runs the GEMM inline
//! single-stripe, which the determinism contract makes bit-identical).
//! Publishing a job is lock-free from the workers' side:
//!
//! 1. the caller writes the [`Job`] fields (stripe geometry + an
//!    erased closure pointer), then Release-stores the stripe count
//!    into `remaining` — the broadcast;
//! 2. anyone (worker or caller) claims a stripe with
//!    `remaining.fetch_sub(1)`; a positive result is a valid claim and
//!    orders the job-field reads after the publish.  A stale worker
//!    that lost the race gets a non-positive result and touches
//!    nothing — job fields are only ever read behind a successful
//!    claim, so a finished job's closure can never be dereferenced;
//! 3. every claim increments `done` exactly once (panics in a stripe
//!    are caught, flagged, and re-thrown on the *caller*, mirroring
//!    the scoped path); the caller retires the job only when
//!    `done == total`, so the closure outlives every reader.
//!
//! The caller always enters the claim loop itself, so a job completes
//! even if every worker is parked, busy, or was never spawned — the
//! pool cannot deadlock a GEMM.  Idle workers spin briefly
//! ([`Backoff`]) then park; the parked flag and the `remaining` check
//! on both sides are SeqCst so a publish and a park can never miss
//! each other.
//!
//! # Determinism
//!
//! The pool partitions `[0, len)` with the same width arithmetic as
//! [`super::dispatch::stripe_ranges`], workers write disjoint stripes,
//! and every kernel keeps its per-element summation order fixed — so
//! results are bit-identical to the scoped and single-threaded paths
//! for any pool size and any claim interleaving (stripe *ownership* is
//! racy; stripe *content* is not).
//!
//! # Sizing
//!
//! `--gemm-pool N` / `QUANTNMT_GEMM_POOL` cap the pool at N lanes
//! (workers + the calling thread); `off` disables it entirely and
//! parallel GEMMs fall back to the scoped path.  [`PoolMode::Auto`]
//! sizes to [`super::gemm_threads`] at first use.  The pool is the
//! single thread budget for the whole process: serving shards in
//! `coordinator::server` share it instead of multiplying
//! `--gemm-threads` by the shard count.

use std::cell::UnsafeCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicIsize, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crossbeam_utils::sync::{Parker, Unparker};
use crossbeam_utils::Backoff;

/// Pool sizing mode, resolved from `--gemm-pool` / `QUANTNMT_GEMM_POOL`
/// (see [`set_gemm_pool`] / [`parse_pool_mode`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PoolMode {
    /// Size the pool to [`super::gemm_threads`] at first use.
    #[default]
    Auto,
    /// Disable the pool: parallel GEMMs use the scoped-spawn fallback.
    Off,
    /// Cap the pool at `n` lanes (workers + the calling thread).
    Lanes(usize),
}

/// Parse a `--gemm-pool` / `QUANTNMT_GEMM_POOL` value: `off` (or `0`)
/// disables the pool, `auto` defers to [`super::gemm_threads`], and a
/// positive integer caps the lane count.
pub fn parse_pool_mode(s: &str) -> Option<PoolMode> {
    match s.trim().to_ascii_lowercase().as_str() {
        "off" | "none" => Some(PoolMode::Off),
        "auto" | "" => Some(PoolMode::Auto),
        t => t.parse::<usize>().ok().map(|n| {
            if n == 0 {
                PoolMode::Off
            } else {
                PoolMode::Lanes(n)
            }
        }),
    }
}

const MODE_AUTO: isize = -1;
const MODE_OFF: isize = 0;
/// `isize::MIN` marks "no override set" (fall through to the env var).
static MODE_OVERRIDE: AtomicIsize = AtomicIsize::new(isize::MIN);

fn encode(mode: PoolMode) -> isize {
    match mode {
        PoolMode::Auto => MODE_AUTO,
        PoolMode::Off => MODE_OFF,
        PoolMode::Lanes(n) => n as isize,
    }
}

/// Set the process-wide pool mode (CLI `--gemm-pool`, or tests/benches
/// A/B-ing dispatch paths).  Workers are spawned lazily at the first
/// parallel GEMM; once spawned the team never grows, so a `Lanes` cap
/// larger than the built pool clamps to it, a smaller cap narrows it,
/// and `Off` falls back to the scoped path from the next call on.
pub fn set_gemm_pool(mode: PoolMode) {
    MODE_OVERRIDE.store(encode(mode), Ordering::Relaxed);
}

fn env_mode() -> isize {
    static ENV: OnceLock<isize> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("QUANTNMT_GEMM_POOL") {
        Ok(v) => match parse_pool_mode(&v) {
            Some(m) => encode(m),
            None => {
                eprintln!("QUANTNMT_GEMM_POOL='{v}' not recognized (want off|auto|N); using auto");
                MODE_AUTO
            }
        },
        Err(_) => MODE_AUTO,
    })
}

fn mode_now() -> isize {
    let o = MODE_OVERRIDE.load(Ordering::Relaxed);
    if o != isize::MIN {
        o
    } else {
        env_mode()
    }
}

/// Whether pooled dispatch is currently enabled (drives the parallel
/// crossover choice in `dispatch::par_flops_min`).
pub(crate) fn enabled() -> bool {
    mode_now() != MODE_OFF
}

static POOL: OnceLock<Pool> = OnceLock::new();

/// The process pool, spawning it on first use — or `None` when the
/// mode is `off` (callers fall back to scoped spawn).
pub(crate) fn get() -> Option<&'static Pool> {
    let m = mode_now();
    if m == MODE_OFF {
        return None;
    }
    Some(POOL.get_or_init(|| {
        let lanes = if m > 0 {
            m as usize
        } else {
            super::dispatch::gemm_threads()
        };
        Pool::new(lanes.max(1))
    }))
}

/// Current pool width in lanes (workers + caller); `0` when disabled.
/// Spawns the pool if the first to ask — meant for logs and benches.
pub fn gemm_pool_lanes() -> usize {
    get().map_or(0, |p| p.lanes())
}

/// An erased stripe job: geometry plus a type-erased `Fn(usize, usize)`
/// borrowed from the submitting caller's stack.  Only read behind a
/// successful stripe claim (see module docs), which is what makes the
/// borrow sound.
#[derive(Clone, Copy)]
struct Job {
    len: usize,
    width: usize,
    total: usize,
    data: *const (),
    call: unsafe fn(*const (), usize, usize),
}

/// Placeholder for the idle slot; never invoked (claims are impossible
/// while `remaining <= 0`).
unsafe fn noop_call(_: *const (), _: usize, _: usize) {}

impl Job {
    const fn idle() -> Job {
        Job { len: 0, width: 1, total: 0, data: std::ptr::null(), call: noop_call }
    }
}

unsafe fn call_thunk<F: Fn(usize, usize) + Sync>(data: *const (), s0: usize, s1: usize) {
    (*(data as *const F))(s0, s1)
}

/// One worker's park state: the flag is the SeqCst half of the
/// publish/park handshake, the unparker the wake handle.
struct ParkSlot {
    flag: AtomicBool,
    unparker: Unparker,
}

/// State shared between the submitting caller and every worker.
struct Shared {
    /// Claim countdown: `> 0` while stripes are unclaimed; the
    /// publish broadcast and the claim ticket in one atomic.
    remaining: AtomicIsize,
    /// Completed-stripe count; the job retires at `done == total`.
    done: AtomicUsize,
    /// A stripe panicked (re-thrown on the caller after the join).
    panicked: AtomicBool,
    /// The job slot.  Written only by the submit-lock holder while
    /// `remaining <= 0` and `done == total`; read (copied) only behind
    /// a successful claim — never concurrently with a write.
    job: UnsafeCell<Job>,
    parked: Vec<ParkSlot>,
}

// SAFETY: the raw pointers in `job` are only dereferenced between a
// successful claim and the matching `done` increment, both inside the
// submitting caller's borrow of the closure (module docs, "Protocol").
unsafe impl Send for Shared {}
unsafe impl Sync for Shared {}

/// Claim and run stripes of the current job until none remain.  Shared
/// by workers and the submitting caller; panics inside a stripe are
/// caught and flagged so `done` always reaches `total` and the caller
/// can never hang on a dead worker.
fn drain_claims(sh: &Shared) {
    loop {
        let c = sh.remaining.fetch_sub(1, Ordering::AcqRel);
        if c <= 0 {
            return;
        }
        // A positive ticket orders these reads after the publish, and
        // the caller can't retire the job before our `done` increment.
        let job = unsafe { *sh.job.get() };
        let idx = job.total - c as usize;
        let s0 = idx * job.width;
        let s1 = (s0 + job.width).min(job.len);
        let ok = catch_unwind(AssertUnwindSafe(|| unsafe { (job.call)(job.data, s0, s1) })).is_ok();
        if !ok {
            sh.panicked.store(true, Ordering::Relaxed);
        }
        sh.done.fetch_add(1, Ordering::Release);
    }
}

fn worker_loop(sh: Arc<Shared>, idx: usize, parker: Parker) {
    let backoff = Backoff::new();
    loop {
        if sh.remaining.load(Ordering::Acquire) > 0 {
            drain_claims(&sh);
            backoff.reset();
            continue;
        }
        if backoff.is_completed() {
            // Spin budget exhausted: park.  Flag-then-check against the
            // publisher's store-then-swap (both SeqCst) guarantees one
            // side sees the other, so a publish can't be missed; a
            // stale unpark token at worst costs one extra loop turn.
            let slot = &sh.parked[idx];
            slot.flag.store(true, Ordering::SeqCst);
            if sh.remaining.load(Ordering::SeqCst) <= 0 {
                parker.park();
            }
            slot.flag.store(false, Ordering::SeqCst);
            backoff.reset();
        } else {
            backoff.snooze();
        }
    }
}

/// The persistent worker team (see module docs).
pub(crate) struct Pool {
    shared: Arc<Shared>,
    /// Lanes the team was built with (workers spawned = built - 1).
    built: usize,
    /// Serializes submitters; contended callers run inline instead of
    /// blocking, so no GEMM ever waits on another caller's GEMM.
    submit: Mutex<()>,
}

impl Pool {
    fn new(lanes: usize) -> Pool {
        let workers = lanes.saturating_sub(1);
        let mut parked = Vec::with_capacity(workers);
        let mut parkers = Vec::with_capacity(workers);
        for _ in 0..workers {
            let p = Parker::new();
            parked.push(ParkSlot { flag: AtomicBool::new(false), unparker: p.unparker().clone() });
            parkers.push(p);
        }
        let shared = Arc::new(Shared {
            remaining: AtomicIsize::new(0),
            done: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            job: UnsafeCell::new(Job::idle()),
            parked,
        });
        let mut built = 1;
        for (idx, parker) in parkers.into_iter().enumerate() {
            let sh = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name(format!("quantnmt-gemm-{idx}"))
                .spawn(move || worker_loop(sh, idx, parker));
            match spawned {
                Ok(_) => built += 1,
                // Out of threads: a narrower pool is still correct
                // (the caller claims whatever workers don't).
                Err(e) => {
                    eprintln!("quantnmt: gemm pool worker spawn failed ({e}); running {built} lanes");
                    break;
                }
            }
        }
        Pool { shared, built, submit: Mutex::new(()) }
    }

    /// Effective lane count: the built width, narrowed by a smaller
    /// runtime `Lanes` cap (the team never grows after spawn).
    pub(crate) fn lanes(&self) -> usize {
        let m = mode_now();
        if m > 0 {
            self.built.min(m as usize).max(1)
        } else {
            self.built
        }
    }

    /// Run `f` over `[0, len)` split into up to `stripes` ranges of
    /// `align`-multiple width (same partition as
    /// `dispatch::stripe_ranges`), claimed by the pool team and the
    /// calling thread.  Returns only when every stripe has run, so `f`
    /// may borrow from the caller's stack.
    pub(crate) fn run<F>(&self, stripes: usize, len: usize, align: usize, f: &F)
    where
        F: Fn(usize, usize) + Sync,
    {
        let width = super::dispatch::stripe_width(len, stripes, align);
        let total = len.div_ceil(width);
        if total <= 1 {
            f(0, len);
            return;
        }
        let guard = match self.submit.try_lock() {
            Ok(g) => g,
            // Another caller owns the team right now; inline is
            // bit-identical (determinism contract) and never blocks.
            Err(_) => {
                f(0, len);
                return;
            }
        };
        let sh = &*self.shared;
        // SAFETY: we hold the submit lock and the previous job retired
        // (`done == total` observed by its submitter), so no claim can
        // read the slot concurrently with this write.
        unsafe {
            *sh.job.get() =
                Job { len, width, total, data: f as *const F as *const (), call: call_thunk::<F> };
        }
        sh.panicked.store(false, Ordering::Relaxed);
        sh.done.store(0, Ordering::Relaxed);
        // The broadcast: claims are valid from here on.
        sh.remaining.store(total as isize, Ordering::SeqCst);
        let mut wake = total - 1;
        for slot in &sh.parked {
            if wake == 0 {
                break;
            }
            if slot.flag.swap(false, Ordering::SeqCst) {
                slot.unparker.unpark();
                wake -= 1;
            }
        }
        // Participate: the job completes even with zero live workers.
        drain_claims(sh);
        let backoff = Backoff::new();
        while sh.done.load(Ordering::Acquire) != total {
            backoff.snooze();
        }
        let poisoned = sh.panicked.load(Ordering::Relaxed);
        drop(guard);
        if poisoned {
            panic!("gemm pool worker panicked");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn parse_pool_mode_values() {
        assert_eq!(parse_pool_mode("off"), Some(PoolMode::Off));
        assert_eq!(parse_pool_mode("0"), Some(PoolMode::Off));
        assert_eq!(parse_pool_mode("auto"), Some(PoolMode::Auto));
        assert_eq!(parse_pool_mode(" 4 "), Some(PoolMode::Lanes(4)));
        assert_eq!(parse_pool_mode("banana"), None);
    }

    #[test]
    fn pool_run_covers_every_stripe_once() {
        let Some(pool) = get() else {
            return; // QUANTNMT_GEMM_POOL=off rerun: scoped path covered elsewhere
        };
        for (len, stripes, align) in
            [(100usize, 4usize, 32usize), (33, 2, 32), (256, 4, 4), (7, 4, 1), (1024, 3, 32)]
        {
            let hits: Vec<AtomicU32> = (0..len).map(|_| AtomicU32::new(0)).collect();
            pool.run(stripes, len, align, &|s0: usize, s1: usize| {
                for h in &hits[s0..s1] {
                    h.fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "({len},{stripes},{align})"
            );
        }
    }

    #[test]
    fn pool_reuse_many_jobs_stays_correct() {
        let Some(pool) = get() else {
            return;
        };
        // many small jobs back to back: exercises park/unpark cycling
        for round in 0..200usize {
            let len = 32 + (round % 7) * 33;
            let sum = AtomicUsize::new(0);
            pool.run(4, len, 1, &|s0: usize, s1: usize| {
                sum.fetch_add((s0..s1).sum::<usize>(), Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), len * (len - 1) / 2, "round {round}");
        }
    }

    #[test]
    fn concurrent_submitters_never_deadlock() {
        let Some(pool) = get() else {
            return;
        };
        // several caller threads race the submit lock; losers must run
        // inline and every caller must get the right answer
        crossbeam_utils::thread::scope(|scope| {
            for t in 0..4usize {
                scope.spawn(move |_| {
                    for round in 0..50usize {
                        let len = 64 + t * 17 + round % 5;
                        let sum = AtomicUsize::new(0);
                        pool.run(4, len, 1, &|s0: usize, s1: usize| {
                            sum.fetch_add((s0..s1).sum::<usize>(), Ordering::Relaxed);
                        });
                        assert_eq!(sum.load(Ordering::Relaxed), len * (len - 1) / 2);
                    }
                });
            }
        })
        .unwrap();
    }

    #[test]
    fn lanes_respect_runtime_cap() {
        let Some(pool) = get() else {
            return;
        };
        let built = pool.built;
        assert_eq!(pool.lanes(), built);
        set_gemm_pool(PoolMode::Lanes(1));
        assert_eq!(pool.lanes(), 1);
        set_gemm_pool(PoolMode::Lanes(built + 8));
        assert_eq!(pool.lanes(), built, "a larger cap clamps to the built team");
        set_gemm_pool(PoolMode::Auto);
        assert_eq!(pool.lanes(), built);
    }
}
