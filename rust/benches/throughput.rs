//! Figure 8 reproduction: the end-to-end throughput ladder.
//!
//! Fig 8a climbs from out-of-the-box FP32 (word-sorted, serial, 1
//! stream) to fully-optimized INT8 (token-sorted, parallel batching,
//! 2-8 streams): paper peak 4.5x.  Fig 8b compares the best INT8
//! configuration against the *best FP32* configuration: paper 1.51x.
//!
//! ```bash
//! cargo bench --bench throughput
//! ```

use quantnmt::coordinator::{Backend, Service, ServiceConfig};
use quantnmt::data::sorting::SortOrder;
use quantnmt::quant::calibrate::CalibrationMode;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let Some(svc) = Service::open_default_or_skip() else {
        return Ok(());
    };
    let ds = svc.dataset()?;
    let n = if quick { 256 } else { ds.test.len() };
    let pairs = &ds.test[..n.min(ds.test.len())];
    let int8_backend = svc.int8_backend(CalibrationMode::Symmetric)?;

    let fp32 = |sort, parallel, streams| ServiceConfig {
        backend: Backend::EngineF32,
        sort,
        parallel,
        streams,
        ..Default::default()
    };
    let int8 = |sort, parallel, streams| ServiceConfig {
        backend: int8_backend.clone(),
        sort,
        parallel,
        streams,
        ..Default::default()
    };

    // Fig 8a ladder: (label, config)
    let ladder: Vec<(&str, ServiceConfig)> = vec![
        ("fp32 word-sorted serial (out-of-box)", fp32(SortOrder::Words, false, 1)),
        ("fp32 token-sorted serial", fp32(SortOrder::Tokens, false, 1)),
        ("fp32 token-sorted parallel x2", fp32(SortOrder::Tokens, true, 2)),
        ("fp32 token-sorted parallel x4", fp32(SortOrder::Tokens, true, 4)),
        ("int8 word-sorted serial", int8(SortOrder::Words, false, 1)),
        ("int8 token-sorted serial", int8(SortOrder::Tokens, false, 1)),
        ("int8 token-sorted parallel x2", int8(SortOrder::Tokens, true, 2)),
        ("int8 token-sorted parallel x4", int8(SortOrder::Tokens, true, 4)),
        ("int8 token-sorted parallel x8", int8(SortOrder::Tokens, true, 8)),
    ];

    println!("== Fig 8a: throughput ladder ({} sentences) ==\n", pairs.len());
    let mut rates = Vec::new();
    let mut base = None;
    for (label, cfg) in &ladder {
        let (m, _) = svc.run(pairs, cfg)?;
        let rate = m.sentences_per_sec();
        let b = *base.get_or_insert(rate);
        println!("{}   x{:.2}", m.row(), rate / b);
        rates.push((label.to_string(), rate, m.bleu));
    }

    // Fig 8b: best INT8 vs best FP32
    let best_fp32 = rates
        .iter()
        .filter(|(l, _, _)| l.starts_with("fp32"))
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    let best_int8 = rates
        .iter()
        .filter(|(l, _, _)| l.starts_with("int8"))
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!("\n== Fig 8b: best-vs-best ==");
    println!("best fp32: {} at {:.2} sent/s (BLEU {:.2})", best_fp32.0, best_fp32.1, best_fp32.2);
    println!("best int8: {} at {:.2} sent/s (BLEU {:.2})", best_int8.0, best_int8.1, best_int8.2);
    println!(
        "int8/fp32 = {:.2}x   (paper: 1.51x; vs out-of-box: {:.2}x, paper 4.5x)",
        best_int8.1 / best_fp32.1,
        best_int8.1 / rates[0].1
    );
    Ok(())
}
