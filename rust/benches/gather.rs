//! §5.3 reproduction: quantized GatherNd on beam-search caches.
//!
//! The paper reduced GatherNd copy volume 3.8x by storing gathered
//! tensors as INT8, making the op ~5x faster.  We benchmark the beam
//! reorder gather over realistic KV-cache geometries in FP32 vs INT8
//! storage and report bytes moved + wall time.
//!
//! ```bash
//! cargo bench --bench gather
//! ```

use quantnmt::model::kvcache::KvCache;
use quantnmt::util::bench::{black_box, Bench};
use quantnmt::util::rng::SplitMix64;

struct Geometry {
    label: &'static str,
    slots: usize,
    slot_len: usize,
}

fn main() {
    let b = Bench::default();
    // batch x beam slots; slot = H * T * dh floats
    let geoms = [
        Geometry { label: "b16 beam4 T32 (self KV)", slots: 64, slot_len: 4 * 32 * 32 },
        Geometry { label: "b64 beam4 T32 (self KV)", slots: 256, slot_len: 4 * 32 * 32 },
        Geometry { label: "b64 beam4 T56 (self KV)", slots: 256, slot_len: 4 * 56 * 32 },
        Geometry { label: "b64 beam4 S48 (cross KV)", slots: 256, slot_len: 4 * 48 * 32 },
    ];
    println!(
        "{:28} {:>12} {:>12} {:>8} {:>14} {:>14}",
        "geometry", "f32", "int8", "speedup", "f32 bytes", "int8 bytes"
    );
    let mut rng = SplitMix64::new(7);
    for g in &geoms {
        let mut cf = KvCache::new_f32(g.slots, g.slot_len);
        let mut cq = KvCache::new_u8(g.slots, g.slot_len, 0.05);
        // fill with data so the gather moves real bytes
        let row: Vec<f32> = (0..g.slot_len).map(|i| (i % 17) as f32 * 0.1).collect();
        for s in 0..g.slots {
            cf.write(s, 0, &row);
            cq.write(s, 0, &row);
        }
        // beam permutation: the typical "keep 2 of 4" shuffle
        let idx: Vec<usize> = (0..g.slots)
            .map(|s| {
                let beam = s % 4;
                let sent = s / 4;
                sent * 4 + if beam < 2 { rng.below(2) as usize } else { beam }
            })
            .collect();
        let mut bytes_f = 0;
        let tf = b.run("f32", || {
            bytes_f = cf.beam_gather(black_box(&idx));
        });
        let mut bytes_q = 0;
        let tq = b.run("i8", || {
            bytes_q = cq.beam_gather(black_box(&idx));
        });
        println!(
            "{:28} {:>9.1} µs {:>9.1} µs {:>7.2}x {:>14} {:>14}",
            g.label,
            tf.median * 1e6,
            tq.median * 1e6,
            tf.median / tq.median,
            bytes_f,
            bytes_q
        );
    }
    println!("\npaper §5.3: copy size ÷3.8, GatherNd time ÷5 (int8 storage = bytes ÷4 exactly)");
}
