//! §5.3 reproduction: quantized gather on paged beam-search caches.
//!
//! The paper reduced GatherNd copy volume 3.8x by storing gathered
//! tensors as INT8, making the op ~5x faster.  Under the paged KV
//! cache the beam-reorder gather itself is a **table permutation** —
//! zero cache bytes move — and data is copied only when a later write
//! lands on a page the gather left shared between slots
//! (copy-on-write).  This bench times that gather over realistic KV
//! geometries and reports the *actual* COW traffic one post-gather
//! decode step provokes, FP32 vs INT8 storage — the honest form of the
//! §5.3 copy metric (int8 pages are exactly 4x smaller, so COW traffic
//! is too) — next to the bytes a dense clone-everything gather would
//! have moved.
//!
//! ```bash
//! cargo bench --bench gather
//! ```

use quantnmt::model::kvcache::{page_positions_from_env, KvCache, PageGeometry, PagePool};
use quantnmt::util::bench::{black_box, Bench};
use quantnmt::util::rng::SplitMix64;

struct Geometry {
    label: &'static str,
    slots: usize,
    positions: usize,
}

const HEADS: usize = 4;
const D_HEAD: usize = 32;

/// A fully written f32 + u8 cache pair over one shared page pool, with
/// 2x page headroom per bank so the post-gather write pass can COW.
fn filled_pair(g: &Geometry, geom: PageGeometry) -> (PagePool, KvCache, KvCache) {
    let pages = geom.pages_for(g.positions);
    let mut pool = PagePool::new(geom, 2 * g.slots * pages, 2 * g.slots * pages);
    let mut cf = KvCache::new_f32(&pool, g.slots, g.positions);
    let mut cq = KvCache::new_u8(&pool, g.slots, g.positions, 0.05);
    let row: Vec<f32> = (0..D_HEAD).map(|i| (i % 17) as f32 * 0.1).collect();
    for s in 0..g.slots {
        assert!(cf.ensure_positions(&mut pool, s, g.positions));
        assert!(cq.ensure_positions(&mut pool, s, g.positions));
        for head in 0..HEADS {
            for t in 0..g.positions {
                cf.write_row(&mut pool, s, head, t, &row);
                cq.write_row(&mut pool, s, head, t, &row);
            }
        }
    }
    (pool, cf, cq)
}

fn main() {
    let b = Bench::default();
    // batch x beam slots over H=4, dh=32 decoder caches
    let geoms = [
        Geometry { label: "b16 beam4 T32 (self KV)", slots: 64, positions: 32 },
        Geometry { label: "b64 beam4 T32 (self KV)", slots: 256, positions: 32 },
        Geometry { label: "b64 beam4 T56 (self KV)", slots: 256, positions: 56 },
        Geometry { label: "b64 beam4 S48 (cross KV)", slots: 256, positions: 48 },
    ];
    let pp = page_positions_from_env();
    println!("page size: {pp} positions x {HEADS} heads x {D_HEAD} (QUANTNMT_KV_PAGE)\n");
    println!(
        "{:26} {:>11} {:>11} {:>8} {:>12} {:>12} {:>13}",
        "geometry", "f32 gather", "i8 gather", "speedup", "f32 COW", "i8 COW", "dense f32"
    );
    let mut rng = SplitMix64::new(7);
    for g in &geoms {
        let geom = PageGeometry { heads: HEADS, d_head: D_HEAD, page_positions: pp };
        let (mut pool, mut cf, mut cq) = filled_pair(g, geom);
        // beam permutation: the typical "keep 2 of 4" shuffle
        let idx: Vec<usize> = (0..g.slots)
            .map(|s| {
                let beam = s % 4;
                let sent = s / 4;
                sent * 4 + if beam < 2 { rng.below(2) as usize } else { beam }
            })
            .collect();
        let tf = b.run("f32", || {
            black_box(cf.beam_gather(&mut pool, black_box(&idx)));
        });
        let tq = b.run("i8", || {
            black_box(cq.beam_gather(&mut pool, black_box(&idx)));
        });
        // one decode step after the gather: every slot writes its tail
        // position, copying exactly the pages the gathers left shared
        let t = g.positions - 1;
        let row = vec![0.25f32; D_HEAD];
        let before = pool.traffic_bytes();
        for s in 0..g.slots {
            for head in 0..HEADS {
                cf.write_row(&mut pool, s, head, t, &row);
            }
        }
        let cow_f = pool.traffic_bytes() - before;
        let before = pool.traffic_bytes();
        for s in 0..g.slots {
            for head in 0..HEADS {
                cq.write_row(&mut pool, s, head, t, &row);
            }
        }
        let cow_q = pool.traffic_bytes() - before;
        // what a dense clone-everything gather would move per call (the
        // old, overstated metric: read + write of every live element)
        let dense_f = 2 * g.slots * HEADS * g.positions * D_HEAD * 4;
        println!(
            "{:26} {:>8.2} µs {:>8.2} µs {:>7.2}x {:>12} {:>12} {:>13}",
            g.label,
            tf.median * 1e6,
            tq.median * 1e6,
            tf.median / tq.median,
            cow_f,
            cow_q,
            dense_f,
        );
    }
    println!(
        "\npaper §5.3: copy size ÷3.8, GatherNd time ÷5.  Paged gather copies nothing up \
         front; COW traffic is the honest copy volume, and int8 storage divides it by 4 exactly."
    );
}
