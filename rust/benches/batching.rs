//! Figure 6/8a reproduction: batching policy x sort order sweep, plus
//! the serial-vs-parallel stream ladder.
//!
//! The paper's parent/children parallel-batching design lifted
//! throughput 43% by overlapping long- and short-sentence batches
//! across affinitized streams, and its bin-packing batch shaping
//! maximizes the fill of every padded batch.  We sweep the three
//! batching policies (fixed-count, token-budget greedy, bin-pack FFD)
//! against the three §5.4 sort orders and report fill ratio and
//! sentences/sec per cell, then run the stream-count ladder under the
//! best policy.
//!
//! ```bash
//! cargo bench --bench batching [-- --quick]
//! ```

use quantnmt::coordinator::{Service, ServiceConfig};
use quantnmt::data::sorting::SortOrder;
use quantnmt::pipeline::policy::PolicyKind;
use quantnmt::quant::calibrate::CalibrationMode;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let Some(svc) = Service::open_default_or_skip() else {
        return Ok(());
    };
    let ds = svc.dataset()?;
    let n = if quick { 256 } else { 1024.min(ds.test.len()) };
    let pairs = &ds.test[..n];
    let int8 = svc.int8_backend(CalibrationMode::Symmetric)?;

    // --- policy x sort sweep (Fig 8a style: fill ratio + sent/s) ----
    println!("corpus: {n} sentences, batch cap 64, token budget 1024, INT8 engine, 2 streams\n");
    println!(
        "{:14} {:>22} {:>22} {:>22}",
        "policy \\ sort", "unsorted", "word-sorted", "token-sorted"
    );
    for policy in PolicyKind::all() {
        let mut cells = Vec::new();
        for sort in [SortOrder::Unsorted, SortOrder::Words, SortOrder::Tokens] {
            let cfg = ServiceConfig {
                backend: int8.clone(),
                sort,
                policy,
                batch_size: 64,
                streams: 2,
                parallel: true,
                ..Default::default()
            };
            let (m, _) = svc.run(pairs, &cfg)?;
            cells.push(format!(
                "fill {:>5.1}% {:>7.1}/s",
                m.fill_ratio() * 100.0,
                m.sentences_per_sec()
            ));
        }
        println!(
            "{:14} {:>22} {:>22} {:>22}",
            policy.as_str(),
            cells[0],
            cells[1],
            cells[2]
        );
    }

    // --- stream ladder under the bin-pack policy (Fig 6) ------------
    println!("\nstream ladder (bin-pack, token-sorted):");
    let mut serial_rate = None;
    for (parallel, streams) in [(false, 1), (true, 2), (true, 4), (true, 8)] {
        let cfg = ServiceConfig {
            backend: int8.clone(),
            policy: PolicyKind::BinPack,
            parallel,
            streams,
            batch_size: 64,
            ..Default::default()
        };
        let (m, _) = svc.run(pairs, &cfg)?;
        let rate = m.sentences_per_sec();
        let base = *serial_rate.get_or_insert(rate);
        println!("{}   x{:.2}", m.row(), rate / base);
    }
    println!("\npaper Fig 6: parallel batching +43% over serial");
    println!("regenerate the EXPERIMENTS.md table with: cargo bench --bench batching");
    Ok(())
}
