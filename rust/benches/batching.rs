//! Figure 6 reproduction: serial vs parallel batch execution.
//!
//! The paper's parent/children parallel-batching design lifted
//! throughput 43% by overlapping long- and short-sentence batches
//! across affinitized streams.  We run the same corpus serially and
//! with 2/4/8 parallel streams and report throughput + utilization.
//!
//! ```bash
//! cargo bench --bench batching
//! ```

use quantnmt::coordinator::{Backend, Service, ServiceConfig};
use quantnmt::quant::calibrate::CalibrationMode;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let svc = Service::open_default()?;
    let ds = svc.dataset()?;
    let n = if quick { 256 } else { 1024.min(ds.test.len()) };
    let pairs = &ds.test[..n];

    println!("corpus: {n} sentences, batch 64, INT8 engine\n");
    let mut serial_rate = None;
    for (parallel, streams) in [(false, 1), (true, 2), (true, 4), (true, 8)] {
        let cfg = ServiceConfig {
            backend: Backend::EngineInt8(CalibrationMode::Symmetric),
            parallel,
            streams,
            batch_size: 64,
            ..Default::default()
        };
        let (m, _) = svc.run(pairs, &cfg)?;
        let rate = m.sentences_per_sec();
        let base = *serial_rate.get_or_insert(rate);
        println!("{}   x{:.2}", m.row(), rate / base);
    }
    println!("\npaper Fig 6: parallel batching +43% over serial");
    Ok(())
}
