//! Table 1 reproduction as a bench target (accuracy + calibration cost).
//!
//! Reports BLEU per calibration mode on the full test set (the Table 1
//! rows) and times the Rust KL-threshold search itself (the §4.2
//! calibration workflow cost the paper folds into its pipeline).
//!
//! ```bash
//! cargo bench --bench calibration
//! ```

use quantnmt::coordinator::{Backend, Service, ServiceConfig};
use quantnmt::quant::calibrate::{CalibrationMode, SiteCalibration};
use quantnmt::quant::histogram::Histogram;
use quantnmt::util::bench::{black_box, Bench};
use quantnmt::util::rng::SplitMix64;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let Some(svc) = Service::open_default_or_skip() else {
        return Ok(());
    };
    let ds = svc.dataset()?;
    let n = if quick { 256 } else { 1024.min(ds.test.len()) };
    let pairs = &ds.test[..n];

    println!("== Table 1: calibration mode vs BLEU ({n} sentences) ==\n");
    let base_cfg = ServiceConfig {
        backend: Backend::EngineF32,
        parallel: false,
        ..Default::default()
    };
    let (m, _) = svc.run(pairs, &base_cfg)?;
    let base = m.bleu;
    println!("{:14} BLEU {:7.2}  (paper 27.68)", "fp32", base);
    for mode in CalibrationMode::all() {
        let cfg = ServiceConfig {
            backend: svc.int8_backend(mode)?,
            parallel: false,
            ..Default::default()
        };
        let (m, _) = svc.run(pairs, &cfg)?;
        println!(
            "{:14} BLEU {:7.2}  drop {:+5.2}   (paper: sym 27.30 / indep 27.33 / conj 27.26 / naive NA)",
            mode.as_str(),
            m.bleu,
            base - m.bleu
        );
    }

    // cost of the KL threshold search itself (2048-bin histogram)
    println!("\n== KL threshold search cost ==");
    let mut rng = SplitMix64::new(3);
    let data: Vec<f32> = (0..500_000)
        .map(|_| {
            let x = rng.normal() as f32;
            if rng.f64() < 0.001 {
                x * 30.0
            } else {
                x
            }
        })
        .collect();
    let mut h = Histogram::new(2048);
    h.observe_range(&data);
    h.observe_fill(&data);
    let b = if quick { Bench::quick() } else { Bench::default() };
    let stats = b.run("kl-search", || {
        black_box(SiteCalibration::from_histogram("bench", &h, 16));
    });
    println!(
        "KL search (3 thresholds, 2048 bins, stride 16): {:.2} ms median",
        stats.median * 1e3
    );
    Ok(())
}
