//! Per-token decode latency: the dispatch-overhead microbench.
//!
//! The per-token decoder step is the worst case for per-op overhead:
//! every MatMul is tiny (`slots x d`), so string formatting, map walks
//! and per-head QuantizeV2 calls show up directly in the token latency
//! rather than being amortized by GEMM work (§4.1/§5.5).  This bench
//! isolates that cost on a **synthetic** model — it runs without
//! artifacts — and prints:
//!
//! * per-token decode latency (best of N reps) for FP32, mixed INT8
//!   and fully-integer (`int8-fused`) engines at slots = 1, 4 and 8,
//!   each under both GEMM dispatch paths — the persistent worker pool
//!   (`pool`) and the `--gemm-pool off` scoped-spawn fallback
//!   (`scoped`) — so the decode-throughput win from pooled dispatch is
//!   measured, not asserted;
//! * deterministic dispatch counts per token (Quantize /
//!   QuantizedMatMul / MatMul invocations from the profiler);
//! * f32↔int conversion **bytes per token** (quantize / dequantize /
//!   requantize passes) — the traffic the fused epilogues eliminate;
//! * the top per-site GEMM times (the `SiteId`-indexed breakdown).
//!
//! Machine-readable results land in `BENCH_requant.json` (one record
//! per engine × slot count).
//!
//! ```bash
//! cargo bench --bench decode            # full sweep
//! cargo bench --bench decode -- --quick # CI smoke
//! ```

use std::time::Instant;

use quantnmt::model::profiler::{OpKind, Profiler};
use quantnmt::model::testutil::{full_int_recipe, loose_recipe, random_weights};
use quantnmt::model::{Engine, ModelConfig};
use quantnmt::util::json::{obj, Json};

fn bench_cfg() -> ModelConfig {
    // paper-adjacent dims, scaled to keep the bench seconds-long
    ModelConfig {
        vocab_size: 96,
        d_model: 256,
        n_heads: 8,
        d_ff: 1024,
        n_enc_layers: 2,
        n_dec_layers: 2,
        max_src_len: 32,
        max_tgt_len: 64,
    }
}

fn source_batch(cfg: &ModelConfig, slots: usize, len: usize) -> Vec<Vec<u32>> {
    (0..slots)
        .map(|i| {
            let mut row: Vec<u32> = (0..len - 1)
                .map(|t| 3 + ((i * 7 + t) % (cfg.vocab_size - 3)) as u32)
                .collect();
            row.push(2); // EOS
            row
        })
        .collect()
}

/// Best-of-reps per-token decode latency in microseconds (full active
/// set — the batch-synchronous schedule over the slot pool).
fn per_token_us(engine: &mut Engine, slots: usize, steps: usize, reps: usize) -> f64 {
    let src = source_batch(&engine.cfg, slots, 16);
    let (memory, src_len, s) = engine.encode(&src);
    let tokens = vec![1u32; slots]; // constant token: latency is shape-bound
    let mut logits = Vec::new();
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let mut pool = engine.new_pool(slots, steps, s);
        let active = engine
            .admit(&mut pool, &memory, &src_len, s)
            .expect("bench pool sized for the batch");
        let t0 = Instant::now();
        for _pos in 0..steps {
            let _ = engine.pool_step(&mut pool, &active, &tokens, &mut logits);
        }
        best = best.min(t0.elapsed().as_secs_f64() / steps as f64 * 1e6);
    }
    best
}

/// Deterministic dispatch profile of one decode step at `pos`: the
/// step's counts *and* the f32↔int conversion byte counters.
fn profiled_step(engine: &mut Engine, slots: usize, pos: usize) -> Profiler {
    let src = source_batch(&engine.cfg, slots, 16);
    let (memory, src_len, s) = engine.encode(&src);
    let mut pool = engine.new_pool(slots, pos + 1, s);
    let active = engine
        .admit(&mut pool, &memory, &src_len, s)
        .expect("bench pool sized for the batch");
    let tokens = vec![1u32; slots];
    let mut logits = Vec::new();
    for _p in 0..pos {
        let _ = engine.pool_step(&mut pool, &active, &tokens, &mut logits);
    }
    engine.profiler = Profiler::enabled();
    let _ = engine.pool_step(&mut pool, &active, &tokens, &mut logits);
    std::mem::take(&mut engine.profiler)
}

/// Finished-slot compaction: per-step GEMM rows at the logits site as
/// the active set shrinks from `slots` live rows down to one — the
/// dead work the old batch-synchronous greedy loop kept paying.
fn compaction_rows(engine: &mut Engine, slots: usize) -> Vec<u64> {
    let src = source_batch(&engine.cfg, slots, 16);
    let (memory, src_len, s) = engine.encode(&src);
    let mut pool = engine.new_pool(slots, slots + 1, s);
    let mut active = engine
        .admit(&mut pool, &memory, &src_len, s)
        .expect("bench pool sized for the batch");
    let mut logits = Vec::new();
    let site = engine.plan().logits;
    let mut rows = Vec::new();
    while !active.is_empty() {
        let tokens = vec![1u32; active.len()];
        engine.profiler = Profiler::enabled();
        let _ = engine.pool_step(&mut pool, &active, &tokens, &mut logits);
        rows.push(engine.profiler.site_rows(site));
        // retire one slot per step, like a staggered-EOS batch
        let done = active.pop().unwrap();
        pool.finish(done);
    }
    engine.profiler = Profiler::default();
    rows
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = bench_cfg();
    let (steps, reps) = if quick { (16, 2) } else { (48, 5) };
    let w = random_weights(&cfg, 42);

    println!(
        "== per-token decode latency (synthetic model: d={} h={} enc={} dec={}) ==\n",
        cfg.d_model, cfg.n_heads, cfg.n_enc_layers, cfg.n_dec_layers
    );
    println!(
        "{:12} {:>6} {:>9} {:>14} {:>10} {:>10} {:>8}",
        "engine", "slots", "dispatch", "us/token", "Quantize", "QMatMul", "MatMul"
    );
    let engines = ["fp32", "int8", "int8-fused"];
    let mk_engine = |kind: &str| -> anyhow::Result<Engine> {
        Ok(match kind {
            "fp32" => Engine::fp32(cfg.clone(), w.clone())?,
            "int8" => Engine::with_recipe(cfg.clone(), w.clone(), &loose_recipe(&cfg))?,
            _ => Engine::with_recipe(cfg.clone(), w.clone(), &full_int_recipe(&cfg))?,
        })
    };
    // (dispatch-mode label, pool mode): the pooled default vs the
    // per-call scoped-spawn fallback.  Dispatch counts are identical
    // across the pair — only wall time may differ — so the profiled
    // step runs once, under pooled dispatch.
    let dispatch_modes =
        [("pool", quantnmt::gemm::PoolMode::Auto), ("scoped", quantnmt::gemm::PoolMode::Off)];
    let mut records: Vec<Json> = Vec::new();
    let mut traffic: Vec<(String, usize, Profiler)> = Vec::new();
    for slots in [1usize, 4, 8] {
        for kind in engines {
            let mut eng = mk_engine(kind)?;
            let p = profiled_step(&mut eng, slots, 8);
            for (dispatch, mode) in dispatch_modes {
                quantnmt::gemm::set_gemm_pool(mode);
                let us = per_token_us(&mut eng, slots, steps, reps);
                println!(
                    "{:12} {:>6} {:>9} {:>14.1} {:>10} {:>10} {:>8}",
                    kind,
                    slots,
                    dispatch,
                    us,
                    p.count(OpKind::Quantize),
                    p.count(OpKind::QuantizedMatMul),
                    p.count(OpKind::MatMul)
                );
                records.push(obj(&[
                    ("engine", kind.into()),
                    ("slots", slots.into()),
                    ("dispatch", dispatch.into()),
                    ("us_per_token", us.into()),
                    ("quantize_count", (p.count(OpKind::Quantize) as f64).into()),
                    ("dequantize_count", (p.count(OpKind::Dequantize) as f64).into()),
                    ("qmatmul_count", (p.count(OpKind::QuantizedMatMul) as f64).into()),
                    ("quantize_bytes", (p.quantize_bytes() as f64).into()),
                    ("dequantize_bytes", (p.dequantize_bytes() as f64).into()),
                    ("requant_bytes", (p.requant_bytes() as f64).into()),
                ]));
            }
            quantnmt::gemm::set_gemm_pool(quantnmt::gemm::PoolMode::Auto);
            traffic.push((kind.to_string(), slots, p));
        }
    }

    // f32<->int conversion traffic: bytes moved through quantize /
    // dequantize passes per token vs bytes through the fused
    // requantize epilogues (input+output bytes of each pass).  The
    // fused engine's q/dq columns are its two per-step boundary hops;
    // everything else rides the rq column.
    println!("\n== f32<->int conversion bytes per token (one step at pos=8) ==\n");
    println!(
        "{:12} {:>6} {:>12} {:>12} {:>12}",
        "engine", "slots", "quant B/tok", "dequant B/tok", "requant B/tok"
    );
    for (kind, slots, p) in &traffic {
        println!(
            "{:12} {:>6} {:>12} {:>12} {:>12}",
            kind,
            slots,
            p.quantize_bytes() / *slots as u64,
            p.dequantize_bytes() / *slots as u64,
            p.requant_bytes() / *slots as u64
        );
    }

    // finished-slot compaction: rows per step must track the active
    // set exactly (slots, slots-1, ..., 1) — the assertion form of the
    // ISSUE's "GEMM rows per step shrink as slots finish"
    let mut int8 = Engine::with_recipe(cfg.clone(), w.clone(), &loose_recipe(&cfg))?;
    let rows = compaction_rows(&mut int8, 8);
    let expect: Vec<u64> = (1..=8u64).rev().collect();
    assert_eq!(rows, expect, "compaction must shed finished slots' rows");
    println!(
        "\nfinished-slot compaction (8 slots, one finishing per step):\n  \
         logits GEMM rows per step: {rows:?}  (batch-synchronous decode: [8, 8, 8, 8, 8, 8, 8, 8])"
    );

    // per-site GEMM attribution over a short decode (SiteId-indexed)
    let mut int8 = Engine::with_recipe(cfg.clone(), w.clone(), &loose_recipe(&cfg))?;
    int8.profiler = Profiler::enabled();
    let src = source_batch(&cfg, 8, 16);
    int8.translate_greedy(&src, steps.min(24));
    println!("\ntop MatMul sites by GEMM wall time (int8, slots=8, pooled dispatch):");
    for (site, total, calls) in int8.profiler.site_breakdown().into_iter().take(8) {
        println!(
            "  {:16} {:>10.3}ms over {:>5} calls",
            int8.plan().site_name(site),
            total.as_secs_f64() * 1e3,
            calls
        );
    }
    println!(
        "\ncounts are deterministic (dispatch structure); times are hardware-dependent.\n\
         see EXPERIMENTS.md \"Dispatch overhead\" for the before/after comparison."
    );

    let doc = obj(&[
        ("bench", "decode-requant".into()),
        ("quick", quick.into()),
        ("d_model", cfg.d_model.into()),
        ("n_dec_layers", cfg.n_dec_layers.into()),
        ("results", Json::Arr(records)),
    ]);
    match std::fs::write("BENCH_requant.json", format!("{doc}\n")) {
        Ok(()) => println!("wrote BENCH_requant.json"),
        Err(e) => eprintln!("could not write BENCH_requant.json: {e}"),
    }
    Ok(())
}
