//! Figure 7 reproduction: per-op time distribution, FP32 vs INT8.
//!
//! The instrumented engine brackets every op family; this bench runs
//! identical workloads through the FP32 and INT8 engines with the
//! profiler enabled and prints the percentage breakdowns side by side —
//! the paper's stacked-bar figure as a table.  Expected shape: MatMul
//! dominates FP32 (paper: 43%); the INT8 graph replaces most of it with
//! QuantizedMatMul while gaining Quantize/Dequantize overhead.
//!
//! ```bash
//! cargo bench --bench op_distribution
//! ```

use quantnmt::coordinator::Service;
use quantnmt::model::profiler::{OpKind, Profiler};
use quantnmt::model::{beam, Engine};
use quantnmt::quant::calibrate::CalibrationMode;
use quantnmt::specials::PAD_ID;

fn profile(engine: &mut Engine, pairs: &[quantnmt::data::Pair], use_beam: bool) -> Profiler {
    engine.profiler = Profiler::enabled();
    for chunk in pairs.chunks(64) {
        let max = chunk.iter().map(|p| p.src.len()).max().unwrap();
        let src: Vec<Vec<u32>> = chunk
            .iter()
            .map(|p| {
                let mut s = p.src.clone();
                s.resize(max, PAD_ID);
                s
            })
            .collect();
        if use_beam {
            beam::translate_beam(engine, &src, beam::BeamConfig::default());
        } else {
            engine.translate_greedy(&src, 56);
        }
    }
    std::mem::take(&mut engine.profiler)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let Some(svc) = Service::open_default_or_skip() else {
        return Ok(());
    };
    let ds = svc.dataset()?;
    let n = if quick { 128 } else { 512.min(ds.test.len()) };
    let pairs = &ds.test[..n];
    let use_beam = true; // the paper decodes with beam search (GatherNd traffic)

    let mut fp32 = Engine::fp32(svc.model_cfg.clone(), svc.weights.clone())?;
    let p_fp32 = profile(&mut fp32, pairs, use_beam);
    let mut int8 = Engine::int8(
        svc.model_cfg.clone(),
        svc.weights.clone(),
        &svc.calibration,
        CalibrationMode::Symmetric,
        false,
    )?;
    let p_int8 = profile(&mut int8, pairs, use_beam);

    println!("== Fig 7: operation-time distribution ({n} sentences, beam 4) ==\n");
    println!("{:20} {:>12} {:>12}", "op", "FP32 %", "INT8 %");
    let pct = |p: &Profiler, k: OpKind| {
        let total = p.grand_total().as_secs_f64();
        if total <= 0.0 {
            0.0
        } else {
            100.0 * p.total(k).as_secs_f64() / total
        }
    };
    for k in OpKind::all() {
        let a = pct(&p_fp32, k);
        let b = pct(&p_int8, k);
        if a > 0.005 || b > 0.005 {
            println!("{:20} {:>11.1}% {:>11.1}%", k.label(), a, b);
        }
    }
    println!(
        "\ntotals: fp32 {:.2}s, int8 {:.2}s  (ratio {:.2}x)",
        p_fp32.grand_total().as_secs_f64(),
        p_int8.grand_total().as_secs_f64(),
        p_fp32.grand_total().as_secs_f64() / p_int8.grand_total().as_secs_f64()
    );
    println!("paper Fig 7: FP32 MatMul 43% -> INT8 shrinks MatMul share, adds Quantize/Dequantize");
    Ok(())
}
