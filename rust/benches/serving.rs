//! Online-serving offered-load sweep: p50/p99 latency vs Poisson load.
//!
//! The serving counterpart of `benches/batching.rs`: instead of packing
//! a known corpus up front, requests arrive one by one on a Poisson
//! clock and the dynamic batcher (`coordinator::server`) must trade
//! batching delay (bounded by `--max-wait-ms`) against batch fill.  The
//! sweep reports, per offered load: completed req/s, p50/p90/p99 total
//! latency, queueing p50, dynamic-batch fill and the shed rate.
//!
//! ```bash
//! cargo bench --bench serving [-- --quick]
//! ```

use std::time::Duration;

use quantnmt::coordinator::server::{poisson_offsets, replay_trace, TranslateRequest};
use quantnmt::coordinator::{ServerConfig, Service};
use quantnmt::quant::calibrate::CalibrationMode;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick" || a == "--test");
    let Some(svc) = Service::open_default_or_skip() else {
        return Ok(());
    };
    let ds = svc.dataset()?;
    let n = if quick { 128 } else { 512.min(ds.test.len()) };
    let n = n.min(ds.test.len());
    let rates = if quick {
        vec![50.0, 200.0]
    } else {
        vec![25.0, 50.0, 100.0, 200.0, 400.0]
    };

    let int8 = svc.int8_backend(CalibrationMode::Symmetric)?;
    for wait_ms in [5u64, 20, 80] {
        let cfg = ServerConfig {
            backend: int8.clone(),
            shards: 2,
            max_wait: Duration::from_millis(wait_ms),
            token_budget: 1024,
            max_batch_rows: 64,
            queue_capacity: 1024,
            max_src_len: None,
            pin_cores: false,
            max_decode_len: 56,
        };
        println!("max-wait {wait_ms}ms, {n} requests per rung:");
        for (rung, &rate) in rates.iter().enumerate() {
            let reqs = TranslateRequest::from_pairs(&ds.test[..n]);
            let offsets = poisson_offsets(0x10AD ^ rung as u64, n, rate);
            let (metrics, _, _) =
                svc.serve(&cfg, |client| replay_trace(client, reqs, &offsets))?;
            println!("  rate {rate:>6.0}/s  {}", metrics.row());
        }
        println!();
    }
    println!("regenerate the EXPERIMENTS.md online table with: cargo bench --bench serving");
    Ok(())
}
