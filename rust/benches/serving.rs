//! Online-serving offered-load sweep: p50/p99 latency vs Poisson load,
//! plus the continuous-vs-batch-synchronous scheduler comparison.
//!
//! The serving counterpart of `benches/batching.rs`: instead of packing
//! a known corpus up front, requests arrive one by one on a Poisson
//! clock and the dynamic batcher (`coordinator::server`) must trade
//! batching delay (bounded by `--max-wait-ms`) against batch fill.  The
//! sweep reports, per offered load: completed req/s, p50/p90/p99 total
//! latency, queueing p50, dynamic-batch fill and the shed rate.
//!
//! The second table sweeps **scheduler × shards × token budget** under
//! one Poisson trace per rung: `--scheduler batch` drains each formed
//! batch to completion, `--scheduler continuous` steps a persistent
//! slot pool with mid-flight admission — same per-request outputs
//! (asserted), different latency/occupancy profile.  See
//! EXPERIMENTS.md "Iteration-level scheduling".
//!
//! ```bash
//! cargo bench --bench serving [-- --quick]
//! ```

use std::time::Duration;

use quantnmt::coordinator::server::{poisson_offsets, replay_trace, Scheduler, TranslateRequest};
use quantnmt::coordinator::{ServerConfig, Service};
use quantnmt::quant::calibrate::CalibrationMode;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick" || a == "--test");
    let Some(svc) = Service::open_default_or_skip() else {
        return Ok(());
    };
    let ds = svc.dataset()?;
    let n = if quick { 128 } else { 512.min(ds.test.len()) };
    let n = n.min(ds.test.len());
    let rates = if quick {
        vec![50.0, 200.0]
    } else {
        vec![25.0, 50.0, 100.0, 200.0, 400.0]
    };

    let int8 = svc.int8_backend(CalibrationMode::Symmetric)?;
    for wait_ms in [5u64, 20, 80] {
        let cfg = ServerConfig {
            backend: int8.clone(),
            shards: 2,
            max_wait: Duration::from_millis(wait_ms),
            token_budget: 1024,
            max_batch_rows: 64,
            queue_capacity: 1024,
            pin_cores: false,
            max_decode_len: 56,
            ..Default::default()
        };
        println!("max-wait {wait_ms}ms, {n} requests per rung:");
        for (rung, &rate) in rates.iter().enumerate() {
            let reqs = TranslateRequest::from_pairs(&ds.test[..n]);
            let offsets = poisson_offsets(0x10AD ^ rung as u64, n, rate);
            let (metrics, _, _) =
                svc.serve(&cfg, |client| replay_trace(client, reqs, &offsets))?;
            println!("  rate {rate:>6.0}/s  {}", metrics.row());
        }
        println!();
    }

    // ---- iteration-level scheduling: continuous vs batch-synchronous ----
    // Poisson arrivals × shards × token budgets, one fixed trace per
    // rung so the two schedulers see identical arrival order; outputs
    // are asserted identical, so every latency/occupancy delta is the
    // scheduler, not the work.
    let shard_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let budgets: &[usize] = if quick { &[512] } else { &[512, 1024] };
    let rate = 200.0;
    println!("scheduler comparison ({n} requests, Poisson {rate:.0}/s, max-wait 20ms):");
    for &shards in shard_counts {
        for &budget in budgets {
            let mut outs: Vec<Vec<Vec<u32>>> = Vec::new();
            for scheduler in [Scheduler::Batch, Scheduler::Continuous] {
                let cfg = ServerConfig {
                    backend: int8.clone(),
                    shards,
                    max_wait: Duration::from_millis(20),
                    token_budget: budget,
                    max_batch_rows: 64,
                    slots: 64,
                    queue_capacity: 4096,
                    pin_cores: false,
                    max_decode_len: 56,
                    scheduler,
                    ..Default::default()
                };
                let reqs = TranslateRequest::from_pairs(&ds.test[..n]);
                let offsets = poisson_offsets(0x17E8 ^ shards as u64, n, rate);
                let (metrics, responses, _) =
                    svc.serve(&cfg, |client| replay_trace(client, reqs, &offsets))?;
                println!("  {}", metrics.row());
                outs.push(responses.into_iter().map(|r| r.out).collect());
            }
            assert_eq!(
                outs[0], outs[1],
                "scheduling parity violated: shards={shards} budget={budget}"
            );
        }
        println!();
    }

    // ---- loopback HTTP/SSE rung: the same Poisson trace through the
    // socket front end vs in-process submission — every delta is the
    // wire (HTTP parse + SSE framing + one connection thread per
    // request), never the scheduler, which is continuous in both.
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    let http_n = if quick { 64.min(n) } else { 256.min(n) };
    let http_rate = if quick { 100.0 } else { 200.0 };
    let offsets = poisson_offsets(0x5EE7, http_n, http_rate);
    let base_reqs = TranslateRequest::from_pairs(&ds.test[..http_n]);
    let srcs: Vec<Vec<u32>> = base_reqs.iter().map(|r| r.src.clone()).collect();
    println!("loopback HTTP/SSE vs in-process ({http_n} requests, Poisson {http_rate:.0}/s):");
    for &shards in shard_counts {
        let cfg = ServerConfig {
            backend: int8.clone(),
            shards,
            max_wait: Duration::from_millis(20),
            token_budget: 1024,
            max_batch_rows: 64,
            slots: 64,
            queue_capacity: 4096,
            pin_cores: false,
            max_decode_len: 56,
            scheduler: Scheduler::Continuous,
            ..Default::default()
        };
        let reqs = base_reqs.clone();
        let (metrics, _, _) = svc.serve(&cfg, |client| replay_trace(client, reqs, &offsets))?;
        println!("  {shards} shard(s)  in-process     {}", metrics.row());

        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = std::thread::scope(|s| -> anyhow::Result<_> {
            let server = {
                let stop = Arc::clone(&stop);
                let cfg = &cfg;
                let svc = &svc;
                s.spawn(move || svc.serve_net(cfg, listener, stop))
            };
            let start = std::time::Instant::now();
            let clients: Vec<_> = srcs
                .iter()
                .zip(&offsets)
                .map(|(src, off)| {
                    let addr = &addr;
                    let due = start + *off;
                    s.spawn(move || {
                        if let Some(w) = due.checked_duration_since(std::time::Instant::now()) {
                            std::thread::sleep(w);
                        }
                        quantnmt::coordinator::net::translate_blocking(addr, src, None)
                    })
                })
                .collect();
            let mut done = 0usize;
            for c in clients {
                if c.join().expect("client thread").is_ok() {
                    done += 1;
                }
            }
            stop.store(true, Ordering::Release);
            let (metrics, _) = server.join().expect("server thread")?;
            assert_eq!(done, http_n, "loopback rung lost responses");
            Ok(metrics)
        })?;
        println!("  {shards} shard(s)  loopback HTTP  {}", metrics.row());
    }
    println!();
    println!("regenerate the EXPERIMENTS.md online tables with: cargo bench --bench serving");
    Ok(())
}
