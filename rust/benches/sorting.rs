//! §5.4 reproduction: input-sentence ordering vs throughput.
//!
//! The paper measures +28% throughput from sorting the input set by
//! *token* count instead of *word* count.  We run the real test corpus
//! through the INT8 engine under all three orderings and report
//! sentences/s plus the padding-waste statistic that explains the gap.
//!
//! ```bash
//! cargo bench --bench sorting
//! ```

use quantnmt::coordinator::{Service, ServiceConfig};
use quantnmt::data::sorting::{padding_waste, sort_indices, SortOrder};
use quantnmt::quant::calibrate::CalibrationMode;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let Some(svc) = Service::open_default_or_skip() else {
        return Ok(());
    };
    let ds = svc.dataset()?;
    let n = if quick { 256 } else { 1024.min(ds.test.len()) };
    let pairs = &ds.test[..n];

    println!("corpus: {n} sentences, batch 64\n");
    println!(
        "{:14} {:>12} {:>14} {:>10}",
        "order", "sent/s", "pad waste", "speedup"
    );
    let mut base = None;
    let int8 = svc.int8_backend(CalibrationMode::Symmetric)?;
    for order in [SortOrder::Unsorted, SortOrder::Words, SortOrder::Tokens] {
        let idx = sort_indices(pairs, order);
        let waste = padding_waste(pairs, &idx, 64);
        let cfg = ServiceConfig {
            backend: int8.clone(),
            sort: order,
            parallel: false,
            batch_size: 64,
            ..Default::default()
        };
        let (m, _) = svc.run(pairs, &cfg)?;
        let rate = m.sentences_per_sec();
        let base_rate = *base.get_or_insert(rate);
        println!(
            "{:14} {:>12.2} {:>13.1}% {:>9.2}x",
            order.as_str(),
            rate,
            waste * 100.0,
            rate / base_rate
        );
    }
    println!("\npaper §5.4: token sorting is +28% over word sorting");
    Ok(())
}
