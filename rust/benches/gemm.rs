//! Figure 3 reproduction: INT8 vs FP32 GEMM speedups.
//!
//! * Fig 3a — square matrices, the generic-shape sweep (paper: 3.7x
//!   peak with VNNI vs FP32 AVX-512);
//! * Fig 3b — the Transformer model's actual GEMM shapes at batch 64
//!   (paper: 2.4x average).
//!
//! We benchmark our own `gemm::sgemm` (FP32 baseline) against
//! `gemm::igemm` (software-VNNI int8); absolute times are this
//! machine's, the *ratios* are the reproduction target.
//!
//! ```bash
//! cargo bench --bench gemm
//! ```

use quantnmt::gemm::{igemm, sgemm};
use quantnmt::model::shapes::{model_shapes, square_shapes, GemmShape};
use quantnmt::model::ModelConfig;
use quantnmt::util::bench::{black_box, Bench};
use quantnmt::util::rng::SplitMix64;

fn bench_shape(b: &Bench, shape: &GemmShape) -> (f64, f64) {
    let (m, k, n) = (shape.m, shape.k, shape.n);
    let mut rng = SplitMix64::new(42);
    let mut af = vec![0.0f32; m * k];
    let mut bf = vec![0.0f32; k * n];
    rng.fill_uniform_f32(&mut af, 1.0);
    rng.fill_uniform_f32(&mut bf, 1.0);
    let ai: Vec<i8> = (0..m * k).map(|_| rng.next_u64() as i8).collect();
    let bi: Vec<u8> = (0..k * n).map(|_| rng.next_u64() as u8).collect();
    let mut cf = vec![0.0f32; m * n];
    let mut ci = vec![0i32; m * n];

    let f32_stats = b.run("f32", || {
        sgemm(m, k, n, black_box(&af), black_box(&bf), &mut cf);
        black_box(&cf);
    });
    let i8_stats = b.run("i8", || {
        igemm(m, k, n, black_box(&ai), black_box(&bi), &mut ci);
        black_box(&ci);
    });
    (f32_stats.median, i8_stats.median)
}

fn report_table(title: &str, shapes: &[GemmShape], b: &Bench) -> f64 {
    println!("\n== {title} ==");
    println!(
        "{:10} {:>6} {:>6} {:>6} {:>12} {:>12} {:>8}",
        "site", "m", "k", "n", "f32", "int8", "speedup"
    );
    let mut speedups = Vec::new();
    for s in shapes {
        let (tf, ti) = bench_shape(b, s);
        let speedup = tf / ti;
        speedups.push(speedup);
        println!(
            "{:10} {:>6} {:>6} {:>6} {:>9.1} µs {:>9.1} µs {:>7.2}x",
            s.site,
            s.m,
            s.k,
            s.n,
            tf * 1e6,
            ti * 1e6,
            speedup
        );
    }
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    let peak = speedups.iter().fold(0.0f64, |m, &x| m.max(x));
    println!("average speedup: {avg:.2}x   peak: {peak:.2}x");
    avg
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bench::quick() } else { Bench::default() };

    // Fig 3a: square sizes (paper sweeps generic GEMM sizes)
    let squares = square_shapes(&[64, 128, 256, 384, 512, 768, 1024]);
    let avg_a = report_table(
        "Fig 3a: square GEMM int8 vs f32 (paper: up to 3.7x)",
        &squares,
        &b,
    );

    // Fig 3b: the model's real shapes at the paper's batch 64
    let cfg = ModelConfig::default();
    let shapes = model_shapes(&cfg, 64, 32, 16);
    let avg_b = report_table(
        "Fig 3b: Transformer GEMM shapes at batch 64 (paper: 2.4x avg)",
        &shapes,
        &b,
    );

    println!("\nsummary: square avg {avg_a:.2}x, model-shape avg {avg_b:.2}x");
}
