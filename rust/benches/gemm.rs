//! Figure 3 reproduction: INT8 vs FP32 GEMM, swept across the kernel
//! ladder and thread counts.
//!
//! * Fig 3a — square matrices, the generic-shape sweep (paper: 3.7x
//!   peak with VNNI vs FP32 AVX-512);
//! * Fig 3b — the Transformer model's actual GEMM shapes at batch 64
//!   (paper: 2.4x average).
//!
//! Per shape we time the FP32 baseline (`sgemm`), then every int8 tier
//! the host supports — portable blocked quad-MAC, AVX2 tiled, the
//! legacy per-row VNNI kernel (`vnni-row`, the pre-tiling baseline) and
//! the register-tiled VNNI macro-kernel — plus the best tier at 2 and 4
//! worker threads.  Absolute times are this machine's; the *ratios* are
//! the reproduction target.
//!
//! A second sweep walks the small-m shapes around the Auto-dispatch
//! pack crossover (`AUTO_PACK_MIN_ROWS` / `AUTO_PACK_MIN_MN`) so the
//! threshold can be re-derived from data.
//!
//! Two pool sweeps document the persistent-worker dispatch layer:
//! a spawn-vs-pool dispatch-latency microbench (the m=1 decode shape,
//! where dispatch cost is the whole story) and a pooled parallelism
//! crossover sweep re-deriving `PAR_FLOPS_MIN_POOLED` from data — both
//! land in `BENCH_pool.json`.
//!
//! Machine-readable results land in `BENCH_gemm.json` (one record per
//! shape x kernel x thread-count: median ns + speedup vs FP32).
//!
//! ```bash
//! cargo bench --bench gemm            # full sweep
//! cargo bench --bench gemm -- --quick # shorter runs, threads = 1 only
//! ```

use quantnmt::gemm::{
    self, igemm_prepacked_scratch, igemm_with_threads, sgemm, vnni, KernelChoice, PackedB,
};
use quantnmt::model::shapes::{model_shapes, square_shapes, GemmShape};
use quantnmt::model::ModelConfig;
use quantnmt::util::bench::{black_box, Bench};
use quantnmt::util::json::{obj, Json};
use quantnmt::util::rng::SplitMix64;

/// One timed (shape, kernel, threads) cell, destined for the JSON dump.
struct Row {
    fig: &'static str,
    site: &'static str,
    m: usize,
    k: usize,
    n: usize,
    kernel: String,
    threads: usize,
    median_ns: f64,
    speedup: f64,
}

impl Row {
    fn to_json(&self) -> Json {
        obj(&[
            ("fig", self.fig.into()),
            ("site", self.site.into()),
            ("m", self.m.into()),
            ("k", self.k.into()),
            ("n", self.n.into()),
            ("kernel", self.kernel.as_str().into()),
            ("threads", self.threads.into()),
            ("median_ns", self.median_ns.into()),
            ("speedup_vs_f32", self.speedup.into()),
        ])
    }
}

/// The int8 tiers this host can run, best last.
fn available_choices() -> Vec<(&'static str, KernelChoice)> {
    let mut v = vec![("portable", KernelChoice::Portable)];
    if gemm::avx2_available() {
        v.push(("avx2", KernelChoice::Avx2));
    }
    if vnni::vnni_available() {
        v.push(("vnni", KernelChoice::Vnni));
    }
    v
}

/// Bench every kernel x thread cell for one shape; returns the rows and
/// prints one summary line.
#[allow(clippy::too_many_arguments)]
fn bench_shape(
    b: &Bench,
    fig: &'static str,
    shape: &GemmShape,
    thread_sweep: &[usize],
    rows: &mut Vec<Row>,
) {
    let (m, k, n) = (shape.m, shape.k, shape.n);
    let mut rng = SplitMix64::new(42);
    let mut af = vec![0.0f32; m * k];
    let mut bf = vec![0.0f32; k * n];
    rng.fill_uniform_f32(&mut af, 1.0);
    rng.fill_uniform_f32(&mut bf, 1.0);
    let ai: Vec<i8> = (0..m * k).map(|_| rng.next_u64() as i8).collect();
    let bi: Vec<u8> = (0..k * n).map(|_| rng.next_u64() as u8).collect();
    let mut cf = vec![0.0f32; m * n];
    let mut ci = vec![0i32; m * n];

    let tf = b
        .run("f32", || {
            sgemm(m, k, n, black_box(&af), black_box(&bf), &mut cf);
            black_box(&cf);
        })
        .median;
    let mut push = |kernel: String, threads: usize, median: f64, rows: &mut Vec<Row>| {
        rows.push(Row {
            fig,
            site: shape.site,
            m,
            k,
            n,
            kernel,
            threads,
            median_ns: median * 1e9,
            speedup: tf / median,
        });
    };
    push("f32".to_string(), 1, tf, rows);

    let mut line = format!(
        "{:10} {:>5} {:>5} {:>5}  f32 {:>9.1}us",
        shape.site,
        m,
        k,
        n,
        tf * 1e6
    );

    // single-threaded ladder (pack cost included: B packs on the fly)
    let choices = available_choices();
    for &(name, choice) in &choices {
        let t = b
            .run(name, || {
                igemm_with_threads(choice, 1, m, k, n, black_box(&ai), black_box(&bi), &mut ci);
                black_box(&ci);
            })
            .median;
        push(name.to_string(), 1, t, rows);
        line.push_str(&format!("  {} {:>9.1}us {:>5.2}x", name, t * 1e6, tf / t));
    }

    // legacy per-row VNNI kernel on a prepacked panel — the baseline the
    // tiled macro-kernel replaces
    if vnni::vnni_available() {
        let bp = PackedB::pack(&bi, k, n);
        let t = b
            .run("vnni-row", || {
                ci.fill(0);
                // SAFETY: vnni_available() checked above.
                unsafe { vnni::igemm_vnni(m, k, black_box(&ai), black_box(&bp), &mut ci) };
                black_box(&ci);
            })
            .median;
        push("vnni-row".to_string(), 1, t, rows);
        line.push_str(&format!("  vnni-row {:>9.1}us {:>5.2}x", t * 1e6, tf / t));
    }

    // best tier across the thread sweep, against a prepacked panel (the
    // serving configuration: weights pack once at plan-compile time)
    let &(best_name, best_choice) = choices.last().unwrap();
    let bp = PackedB::pack(&bi, k, n);
    let mut a_pack = Vec::new();
    for &threads in thread_sweep {
        let t = b
            .run(best_name, || {
                igemm_prepacked_scratch(
                    best_choice,
                    threads,
                    m,
                    k,
                    black_box(&ai),
                    black_box(&bp),
                    &mut ci,
                    &mut a_pack,
                );
                black_box(&ci);
            })
            .median;
        push(format!("{best_name}+pre"), threads, t, rows);
        line.push_str(&format!(
            "  {}+pre@{} {:>9.1}us {:>5.2}x",
            best_name,
            threads,
            t * 1e6,
            tf / t
        ));
    }
    println!("{line}");
}

fn report_table(
    title: &str,
    fig: &'static str,
    shapes: &[GemmShape],
    b: &Bench,
    thread_sweep: &[usize],
    rows: &mut Vec<Row>,
) -> f64 {
    println!("\n== {title} ==");
    let before = rows.len();
    for s in shapes {
        bench_shape(b, fig, s, thread_sweep, rows);
    }
    // average speedup of the best single-threaded int8 kernel per shape
    let mut speedups = Vec::new();
    for s in shapes {
        let best = rows[before..]
            .iter()
            .filter(|r| r.site == s.site && r.m == s.m && r.n == s.n && r.kernel != "f32")
            .filter(|r| r.threads == 1)
            .map(|r| r.speedup)
            .fold(0.0f64, f64::max);
        if best > 0.0 {
            speedups.push(best);
        }
    }
    let avg = speedups.iter().sum::<f64>() / speedups.len().max(1) as f64;
    let peak = speedups.iter().fold(0.0f64, |m, &x| m.max(x));
    println!("best int8 (1 thread) vs f32: average {avg:.2}x   peak {peak:.2}x");
    avg
}

/// Walk small-m shapes around the Auto-dispatch pack crossover:
/// portable (no pack) vs the best packed tier (pack cost included),
/// both single-threaded.  Documents `AUTO_PACK_MIN_ROWS` /
/// `AUTO_PACK_MIN_MN`.
fn crossover_sweep(b: &Bench, out: &mut Vec<Json>) {
    let choices = available_choices();
    let &(best_name, best_choice) = choices.last().unwrap();
    if best_choice == KernelChoice::Portable {
        println!("\n== pack crossover: no SIMD tier on this host, skipped ==");
        return;
    }
    println!("\n== pack crossover: portable vs {best_name} (pack included, 1 thread) ==");
    println!(
        "current policy: pack when m >= {} and m*n >= {}",
        gemm::AUTO_PACK_MIN_ROWS,
        gemm::AUTO_PACK_MIN_MN
    );
    let k = 512usize;
    let mut rng = SplitMix64::new(7);
    for &m in &[1usize, 2, 4, 8] {
        for &n in &[64usize, 256, 1024] {
            let ai: Vec<i8> = (0..m * k).map(|_| rng.next_u64() as i8).collect();
            let bi: Vec<u8> = (0..k * n).map(|_| rng.next_u64() as u8).collect();
            let mut ci = vec![0i32; m * n];
            let tp = b
                .run("portable", || {
                    igemm_with_threads(
                        KernelChoice::Portable,
                        1,
                        m,
                        k,
                        n,
                        black_box(&ai),
                        black_box(&bi),
                        &mut ci,
                    );
                    black_box(&ci);
                })
                .median;
            let ts = b
                .run(best_name, || {
                    igemm_with_threads(
                        best_choice,
                        1,
                        m,
                        k,
                        n,
                        black_box(&ai),
                        black_box(&bi),
                        &mut ci,
                    );
                    black_box(&ci);
                })
                .median;
            let packed_wins = ts < tp;
            let auto_packs = m >= gemm::AUTO_PACK_MIN_ROWS && m * n >= gemm::AUTO_PACK_MIN_MN;
            println!(
                "m={m:<2} k={k} n={n:<5} portable {:>9.1}us  packed {:>9.1}us  ratio {:>5.2}x  \
                 packed_wins={packed_wins}  auto_packs={auto_packs}",
                tp * 1e6,
                ts * 1e6,
                tp / ts
            );
            out.push(obj(&[
                ("m", m.into()),
                ("k", k.into()),
                ("n", n.into()),
                ("portable_ns", (tp * 1e9).into()),
                ("packed_ns", (ts * 1e9).into()),
                ("packed_kernel", best_name.into()),
                ("packed_wins", packed_wins.into()),
                ("auto_packs", auto_packs.into()),
            ]));
        }
    }
}

/// Spawn-vs-pool dispatch latency on the m=1 decode shape: the GEMM is
/// tiny, so the measured gap between the parallel paths and the inline
/// baseline is almost pure dispatch cost.  The issue's acceptance bar:
/// pooled dispatch >= 10x cheaper than scoped spawn+join here.
fn dispatch_overhead_bench(b: &Bench, out: &mut Vec<Json>) {
    let (m, k, n) = (1usize, 512usize, 512usize);
    let mut rng = SplitMix64::new(17);
    let ai: Vec<i8> = (0..m * k).map(|_| rng.next_u64() as i8).collect();
    let bi: Vec<u8> = (0..k * n).map(|_| rng.next_u64() as u8).collect();
    let mut ci = vec![0i32; m * n];
    println!("\n== dispatch overhead: m={m} k={k} n={n} (explicit 4 threads) ==");
    let mut time_mode = |label: &str, mode: gemm::PoolMode, threads: usize| {
        gemm::set_gemm_pool(mode);
        let t = b
            .run(label, || {
                igemm_with_threads(
                    KernelChoice::Auto,
                    threads,
                    m,
                    k,
                    n,
                    black_box(&ai),
                    black_box(&bi),
                    &mut ci,
                );
                black_box(&ci);
            })
            .median;
        println!("  {label:<12} {:>9.2}us", t * 1e6);
        t
    };
    let t_inline = time_mode("inline", gemm::PoolMode::Auto, 1);
    let t_pool = time_mode("pool", gemm::PoolMode::Auto, 4);
    let t_scoped = time_mode("scoped-spawn", gemm::PoolMode::Off, 4);
    gemm::set_gemm_pool(gemm::PoolMode::Auto);
    // dispatch cost ~= parallel time minus the inline compute floor
    let d_pool = (t_pool - t_inline).max(0.0);
    let d_scoped = (t_scoped - t_inline).max(0.0);
    let ratio = if d_pool > 0.0 { d_scoped / d_pool } else { f64::INFINITY };
    println!(
        "  dispatch overhead: scoped {:.2}us vs pooled {:.2}us ({ratio:.1}x; target >= 10x)",
        d_scoped * 1e6,
        d_pool * 1e6
    );
    out.push(obj(&[
        ("m", m.into()),
        ("k", k.into()),
        ("n", n.into()),
        ("threads", 4usize.into()),
        ("inline_ns", (t_inline * 1e9).into()),
        ("pool_ns", (t_pool * 1e9).into()),
        ("scoped_ns", (t_scoped * 1e9).into()),
        ("dispatch_pool_ns", (d_pool * 1e9).into()),
        ("dispatch_scoped_ns", (d_scoped * 1e9).into()),
        ("scoped_over_pool", ratio.into()),
    ]));
}

/// Re-derive the pooled parallelism crossover from data: for each
/// shape, 1 thread vs 4 pooled lanes.  The smallest flop count where
/// pooled-parallel wins is where `PAR_FLOPS_MIN_POOLED` should sit
/// (override with `QUANTNMT_GEMM_PAR_MIN` when this machine disagrees
/// with the constant).
fn pool_crossover_sweep(b: &Bench, out: &mut Vec<Json>) {
    println!(
        "\n== pooled parallel crossover (current PAR_FLOPS_MIN_POOLED = {}, scoped {}) ==",
        gemm::PAR_FLOPS_MIN_POOLED,
        gemm::PAR_FLOPS_MIN
    );
    gemm::set_gemm_pool(gemm::PoolMode::Auto);
    let mut rng = SplitMix64::new(23);
    for &(m, k, n) in &[
        (1usize, 128usize, 128usize), // 32k flops: below the crossover
        (1, 256, 256),                // 131k = the crossover constant
        (1, 512, 512),                // 0.5M: the decode logits-ish shape
        (4, 512, 512),                // 2M: slots=4 decode step
        (8, 512, 1024),               // 8M: above even the scoped bar
    ] {
        let ai: Vec<i8> = (0..m * k).map(|_| rng.next_u64() as i8).collect();
        let bi: Vec<u8> = (0..k * n).map(|_| rng.next_u64() as u8).collect();
        let mut ci = vec![0i32; m * n];
        let mut run_at = |label: &str, threads: usize| {
            b.run(label, || {
                igemm_with_threads(
                    KernelChoice::Auto,
                    threads,
                    m,
                    k,
                    n,
                    black_box(&ai),
                    black_box(&bi),
                    &mut ci,
                );
                black_box(&ci);
            })
            .median
        };
        let t1 = run_at("pool-x1", 1);
        let t4 = run_at("pool-x4", 4);
        let flops = 2 * m * k * n;
        let parallel_wins = t4 < t1;
        let auto_parallel = flops >= gemm::PAR_FLOPS_MIN_POOLED;
        println!(
            "m={m:<2} k={k:<4} n={n:<5} flops {flops:>9}  x1 {:>9.1}us  x4 {:>9.1}us  \
             ratio {:>5.2}x  parallel_wins={parallel_wins}  auto_parallel={auto_parallel}",
            t1 * 1e6,
            t4 * 1e6,
            t1 / t4
        );
        out.push(obj(&[
            ("m", m.into()),
            ("k", k.into()),
            ("n", n.into()),
            ("flops", flops.into()),
            ("x1_ns", (t1 * 1e9).into()),
            ("x4_pooled_ns", (t4 * 1e9).into()),
            ("parallel_wins", parallel_wins.into()),
            ("auto_parallel", auto_parallel.into()),
        ]));
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bench::quick() } else { Bench::default() };
    let thread_sweep: &[usize] = if quick { &[1] } else { &[1, 2, 4] };

    println!(
        "isa: {}  process threads: {}  sweep: {:?}",
        gemm::isa_level().as_str(),
        gemm::gemm_threads(),
        thread_sweep
    );

    let mut rows: Vec<Row> = Vec::new();

    // Fig 3a: square sizes (paper sweeps generic GEMM sizes)
    let squares = square_shapes(&[64, 128, 256, 384, 512, 768, 1024]);
    let avg_a = report_table(
        "Fig 3a: square GEMM int8 vs f32 (paper: up to 3.7x)",
        "3a",
        &squares,
        &b,
        thread_sweep,
        &mut rows,
    );

    // Fig 3b: the model's real shapes at the paper's batch 64
    let cfg = ModelConfig::default();
    let shapes = model_shapes(&cfg, 64, 32, 16);
    let avg_b = report_table(
        "Fig 3b: Transformer GEMM shapes at batch 64 (paper: 2.4x avg)",
        "3b",
        &shapes,
        &b,
        thread_sweep,
        &mut rows,
    );

    let mut crossover = Vec::new();
    crossover_sweep(&b, &mut crossover);

    let mut dispatch = Vec::new();
    dispatch_overhead_bench(&b, &mut dispatch);
    let mut pool_crossover = Vec::new();
    pool_crossover_sweep(&b, &mut pool_crossover);
    let pool_doc = obj(&[
        ("isa", gemm::isa_level().as_str().into()),
        ("pool_lanes", gemm::gemm_pool_lanes().into()),
        ("quick", quick.into()),
        ("dispatch", Json::Arr(dispatch)),
        ("crossover", Json::Arr(pool_crossover)),
    ]);
    match std::fs::write("BENCH_pool.json", format!("{pool_doc}\n")) {
        Ok(()) => println!("wrote BENCH_pool.json"),
        Err(e) => eprintln!("could not write BENCH_pool.json: {e}"),
    }

    println!("\nsummary: square avg {avg_a:.2}x, model-shape avg {avg_b:.2}x");

    let doc = obj(&[
        ("isa", gemm::isa_level().as_str().into()),
        ("quick", quick.into()),
        (
            "thread_sweep",
            Json::Arr(thread_sweep.iter().map(|&t| t.into()).collect()),
        ),
        (
            "results",
            Json::Arr(rows.iter().map(Row::to_json).collect()),
        ),
        ("crossover", Json::Arr(crossover)),
    ]);
    match std::fs::write("BENCH_gemm.json", format!("{doc}\n")) {
        Ok(()) => println!("wrote BENCH_gemm.json ({} records)", rows.len()),
        Err(e) => eprintln!("could not write BENCH_gemm.json: {e}"),
    }
}
