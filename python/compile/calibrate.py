"""Post-training calibration: histograms, KL thresholds, tensor classes.

Implements §4.2 of the paper end to end (mirrored in rust/src/quant):

1. run the trained FP32 model over the 600-sentence calibration subset,
   collecting per-MatMul-site activation histograms (two passes: one for
   ranges, one to fill fixed-range histograms);
2. classify each site's distribution as sparse / narrow / Gaussian
   (Fig 2) — sparse sites are left unquantized;
3. search saturation thresholds that minimize the KL divergence between
   the FP32 histogram and its int8 quantization (Migacz'17 procedure),
   under the paper's three modes:

   * ``symmetric``   — KL on the |x| distribution, Tmin = -Tmax
   * ``independent`` — separate KL searches for the negative and
                       positive halves (non-zero zero point)
   * ``conjugate``   — independent, then Tmax = max(|Tmin|, |Tmax|)

   plus ``naive`` (absolute min/max, §4.1) as the failing baseline.
"""

import math
from dataclasses import dataclass, field

import numpy as np

from .common import (
    HIST_BINS,
    QUANT_BINS,
    INT8_MAX,
    DataConfig,
    ModelConfig,
)
from . import model as M
from .datagen import pad_batch

EPS = 1e-12

# classifier knobs (Fig 2); mirrored in rust/src/quant/classify.rs
SPARSE_ZERO_FRAC = 0.50    # >50% of samples exactly/near zero -> sparse
NARROW_RANGE = 1.5         # dynamic range below this -> narrow
NEAR_ZERO = 1e-6


@dataclass
class SiteStats:
    """Streaming per-site statistics + fixed-range histogram."""

    min: float = math.inf
    max: float = -math.inf
    count: int = 0
    zeros: int = 0
    sum: float = 0.0
    sumsq: float = 0.0
    # filled in pass 2:
    hist_pos: np.ndarray = None   # histogram of x > 0 over [0, max]
    hist_neg: np.ndarray = None   # histogram of -x for x < 0 over [0, -min]
    hist_abs: np.ndarray = None   # histogram of |x| over [0, absmax]

    def observe_range(self, x: np.ndarray):
        x = x.ravel()
        self.min = min(self.min, float(x.min()))
        self.max = max(self.max, float(x.max()))
        self.count += x.size
        self.zeros += int((np.abs(x) < NEAR_ZERO).sum())
        self.sum += float(x.sum())
        self.sumsq += float((x * x).sum())

    @property
    def absmax(self):
        return max(abs(self.min), abs(self.max), EPS)

    def observe_hist(self, x: np.ndarray):
        if self.hist_abs is None:
            self.hist_abs = np.zeros(HIST_BINS)
            self.hist_pos = np.zeros(HIST_BINS)
            self.hist_neg = np.zeros(HIST_BINS)
        x = x.ravel()
        # exclude (near-)zeros from all three histograms: zeros quantize
        # to 0 exactly under any threshold, and their spike otherwise
        # dominates P and skews the KL search toward over-tight clips
        # (visible on one-sided post-ReLU tensors).
        ax = np.abs(x[np.abs(x) > NEAR_ZERO])
        self.hist_abs += np.histogram(ax, bins=HIST_BINS, range=(0, self.absmax))[0]
        pos = x[x > NEAR_ZERO]
        neg = -x[x < -NEAR_ZERO]
        if pos.size and self.max > 0:
            self.hist_pos += np.histogram(pos, bins=HIST_BINS, range=(0, max(self.max, EPS)))[0]
        if neg.size and self.min < 0:
            self.hist_neg += np.histogram(neg, bins=HIST_BINS, range=(0, -min(self.min, -EPS)))[0]

    def classify(self) -> str:
        """sparse / narrow / gaussian (Fig 2)."""
        if self.count == 0:
            return "narrow"
        if self.zeros / self.count > SPARSE_ZERO_FRAC:
            return "sparse"
        if (self.max - self.min) < NARROW_RANGE:
            return "narrow"
        return "gaussian"


def kl_divergence(p: np.ndarray, q: np.ndarray) -> float:
    """KL(P||Q) with smoothing over empty Q bins (TensorRT recipe)."""
    p = p.astype(np.float64)
    q = q.astype(np.float64)
    ps = p.sum()
    qs = q.sum()
    if ps <= 0 or qs <= 0:
        return math.inf
    p = p / ps
    q = q / qs
    mask = p > 0
    q = np.where(q > 0, q, EPS)
    return float(np.sum(p[mask] * np.log(p[mask] / q[mask])))


def quantize_hist(ref: np.ndarray, levels: int = QUANT_BINS) -> np.ndarray:
    """Collapse ``ref`` into ``levels`` buckets and re-expand, preserving
    mass only over originally non-empty bins (Migacz'17)."""
    n = len(ref)
    out = np.zeros(n)
    edges = np.linspace(0, n, levels + 1).astype(int)
    for lo, hi in zip(edges[:-1], edges[1:]):
        if hi <= lo:
            continue
        chunk = ref[lo:hi]
        nz = chunk > 0
        k = int(nz.sum())
        if k == 0:
            continue
        out[lo:hi][nz] = chunk[nz].sum() / k
    return out


def kl_threshold(hist: np.ndarray, bin_width: float,
                 min_bins: int = QUANT_BINS, stride: int = 16) -> float:
    """Find the saturation threshold minimizing KL(P||Q).

    hist: histogram of non-negative magnitudes over [0, bins*bin_width].
    Scans candidate clip points i in [min_bins, len(hist)]; outlier mass
    beyond i is folded into the last kept bin of P (saturation).
    """
    total = hist.sum()
    if total <= 0:
        return max(bin_width * len(hist), EPS)
    best_i, best_kl = len(hist), math.inf
    for i in range(min_bins, len(hist) + 1, stride):
        # P: clipped histogram with the outlier mass folded into the edge
        # bin (that is what saturation does to the real distribution).
        p = hist[:i].astype(np.float64).copy()
        outliers = hist[i:].sum()
        p[-1] += outliers
        # Q: quantized from the *unfolded* clipped histogram — the
        # asymmetry (P sees the fold, Q does not) is what penalizes
        # aggressive clipping; quantizing the folded P instead makes
        # i=min_bins trivially optimal (KL=0) and wrecks accuracy.
        q = quantize_hist(hist[:i].astype(np.float64))
        kl = kl_divergence(p, q)
        if kl < best_kl:
            best_kl, best_i = kl, i
    return best_i * bin_width


@dataclass
class SiteCalibration:
    """Everything the quantizer needs for one MatMul site (JSON-exported)."""

    name: str
    klass: str                      # sparse | narrow | gaussian
    amin: float
    amax: float
    thr_symmetric: float            # T: range [-T, T]
    thr_independent: tuple          # (Tmin, Tmax)
    thr_conjugate: float
    count: int
    zero_frac: float
    mean: float
    std: float

    def to_dict(self):
        return {
            "name": self.name,
            "class": self.klass,
            "min": self.amin,
            "max": self.amax,
            "symmetric": self.thr_symmetric,
            "independent": list(self.thr_independent),
            "conjugate": self.thr_conjugate,
            "count": self.count,
            "zero_frac": self.zero_frac,
            "mean": self.mean,
            "std": self.std,
        }


def calibrate_site(name: str, st: SiteStats) -> SiteCalibration:
    t_sym = kl_threshold(st.hist_abs, st.absmax / HIST_BINS)
    t_pos = (
        kl_threshold(st.hist_pos, max(st.max, EPS) / HIST_BINS)
        if st.max > 0 else EPS
    )
    t_neg = (
        kl_threshold(st.hist_neg, max(-st.min, EPS) / HIST_BINS)
        if st.min < 0 else EPS
    )
    mean = st.sum / max(st.count, 1)
    var = max(st.sumsq / max(st.count, 1) - mean * mean, 0.0)
    return SiteCalibration(
        name=name,
        klass=st.classify(),
        amin=st.min,
        amax=st.max,
        thr_symmetric=t_sym,
        thr_independent=(-t_neg, t_pos),
        thr_conjugate=max(t_pos, t_neg),
        count=st.count,
        zero_frac=st.zeros / max(st.count, 1),
        mean=mean,
        std=math.sqrt(var),
    )


# --------------------------------------------------------------------------
# scale/zero-point derivation per mode (mirrors rust quant::scheme)
# --------------------------------------------------------------------------

def scale_for_mode(cal: SiteCalibration, mode: str):
    """Returns (a_scale, a_zero) for quantizing the site's A operand."""
    if mode == "naive":
        lo, hi = cal.amin, cal.amax
        t = max(abs(lo), abs(hi), EPS)
        return t / INT8_MAX, 0
    if mode == "symmetric":
        return max(cal.thr_symmetric, EPS) / INT8_MAX, 0
    if mode == "conjugate":
        return max(cal.thr_conjugate, EPS) / INT8_MAX, 0
    if mode == "independent":
        tmin, tmax = cal.thr_independent
        tmin = min(tmin, -EPS)
        tmax = max(tmax, EPS)
        scale = (tmax - tmin) / 255.0
        zero = int(round(-128 - tmin / scale))
        zero = max(-128, min(127, zero))
        return scale, zero
    raise ValueError(mode)


def collect_statistics(params, cfg: ModelConfig, calib_pairs, batch_size: int = 64,
                       log=print):
    """Two-pass histogram collection over the calibration set.

    Runs the *teacher-forced* FP32 forward (same MatMul sites and
    activation distributions as inference) un-jitted so the collector
    callback sees concrete values.
    """
    import jax.numpy as jnp  # noqa: F401  (model functions use jnp)

    stats: dict = {}

    def make_collector(phase):
        def collect(site_side, tensor):
            site, side = site_side.rsplit(".", 1)
            wname = M.weight_for_site(cfg, site)
            if side == "b" and wname is not None:
                return  # weights are calibrated from their own values
            key = site if side == "a" else site_side
            st = stats.setdefault(key, SiteStats())
            x = np.asarray(tensor)
            if phase == "range":
                st.observe_range(x)
            else:
                st.observe_hist(x)
        return collect

    def run(phase):
        for i in range(0, len(calib_pairs), batch_size):
            chunk = calib_pairs[i : i + batch_size]
            src = pad_batch([p["src"] for p in chunk], cfg.max_src_len)
            tgt_in = pad_batch([p["ref"][:-1] for p in chunk], cfg.max_tgt_len,
                               bos=True)
            M.forward_teacher(params, cfg, src, tgt_in,
                              collect=make_collector(phase))
            log(f"  calib {phase}: {min(i + batch_size, len(calib_pairs))}"
                f"/{len(calib_pairs)}")

    run("range")
    run("hist")
    return stats


def calibrate_model(params, cfg: ModelConfig, calib_pairs, log=print):
    """Full calibration: returns {site -> SiteCalibration} for A sides and
    dynamic-B sides (keys 'site' and 'site.b' respectively)."""
    stats = collect_statistics(params, cfg, calib_pairs, log=log)
    out = {}
    for key, st in stats.items():
        out[key] = calibrate_site(key, st)
        log(f"  site {key:24s} class={out[key].klass:8s} "
            f"range=[{st.min:+.3f},{st.max:+.3f}] Tsym={out[key].thr_symmetric:.3f}")
    return out


def load_calibration(path):
    """Inverse of the aot.py export: calibration.json -> (cals, wscales)."""
    import json

    with open(path) as f:
        j = json.load(f)
    cals = {}
    for name, s in j["sites"].items():
        cals[name] = SiteCalibration(
            name=s["name"],
            klass=s["class"],
            amin=s["min"],
            amax=s["max"],
            thr_symmetric=s["symmetric"],
            thr_independent=tuple(s["independent"]),
            thr_conjugate=s["conjugate"],
            count=s["count"],
            zero_frac=s["zero_frac"],
            mean=s["mean"],
            std=s["std"],
        )
    return cals, j["weight_scales"]


def weight_scales(params, cfg: ModelConfig):
    """Symmetric per-tensor u8 scales for every weight MatMul operand."""
    scales = {}
    for site in M.matmul_site_names(cfg):
        wname = M.weight_for_site(cfg, site)
        if wname is None:
            continue
        w = params["embed"].T if wname == "embed.T" else params[wname]
        absmax = float(np.abs(np.asarray(w)).max())
        scales[site] = max(absmax, EPS) / INT8_MAX
    return scales


def build_site_table(cfg: ModelConfig, cals: dict, wscales: dict, mode: str,
                     skip_sparse: bool = True):
    """Assemble the model.make_qctx input for a calibration mode.

    Sparse-classified sites are left unquantized (paper: 12/97 MatMuls).
    For dynamic (tensor x tensor) sites the B operand uses its own
    calibrated symmetric threshold.
    """
    table = {}
    for site in M.matmul_site_names(cfg):
        cal = cals.get(site)
        if cal is None:
            continue
        if skip_sparse and cal.klass == "sparse":
            table[site] = None
            continue
        a_scale, a_zero = scale_for_mode(cal, mode)
        if site in wscales:
            b_scale = wscales[site]
        else:
            bcal = cals.get(site + ".b")
            if bcal is None:
                table[site] = None
                continue
            if skip_sparse and bcal.klass == "sparse":
                table[site] = None
                continue
            b_mode = mode if mode != "independent" else "conjugate"
            b_scale, _ = scale_for_mode(bcal, b_mode)
        table[site] = (a_scale, a_zero, b_scale)
    return table
