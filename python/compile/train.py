"""Build-time training of the FP32 Transformer on the synthetic task.

The paper starts from a *trained* FP32 model (BLEU 27.68) and quantizes
it post-training.  This module produces our equivalent starting point:
a model trained to near-ceiling accuracy on the synthetic translation
task, so that quantization-induced BLEU drops are measurable.

No optax in this environment — Adam with linear warmup + inverse-sqrt
decay (the Transformer paper's schedule) is hand-rolled below.
"""

import math
import time

import jax
import jax.numpy as jnp

from .common import ModelConfig, DataConfig, TrainConfig
from .datagen import TrainStream
from . import model as M


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.98, eps=1e-9):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * jnp.square(grads[k]) for k in params}
    mhat = {k: m[k] / (1 - b1**t) for k in params}
    vhat = {k: v[k] / (1 - b2**t) for k in params}
    new = {k: params[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps) for k in params}
    return new, {"m": m, "v": v, "t": t}


def lr_schedule(step, cfg: TrainConfig):
    """Linear warmup to peak_lr, then inverse-sqrt decay."""
    step = max(step, 1)
    warm = cfg.peak_lr * step / max(cfg.warmup, 1)
    decay = cfg.peak_lr * math.sqrt(cfg.warmup / step) if step > cfg.warmup else warm
    return min(warm, decay) if step <= cfg.warmup else decay


def train(model_cfg: ModelConfig = None, data_cfg: DataConfig = None,
          train_cfg: TrainConfig = None, log_every: int = 100, log=print):
    """Returns (params, loss_history)."""
    model_cfg = model_cfg or ModelConfig()
    data_cfg = data_cfg or DataConfig()
    train_cfg = train_cfg or TrainConfig()

    stream = TrainStream(data_cfg, model_cfg, train_cfg.batch_size,
                         seed=train_cfg.seed ^ 0x5EED)
    params = M.init_params(model_cfg, jax.random.PRNGKey(train_cfg.seed))
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt_m, opt_v, opt_t, lr, src, tgt_in, tgt_out):
        loss, grads = jax.value_and_grad(M.loss_fn)(
            params, model_cfg, src, tgt_in, tgt_out
        )
        state = {"m": opt_m, "v": opt_v, "t": opt_t}
        new_params, new_state = adam_update(params, grads, state, lr)
        return loss, new_params, new_state["m"], new_state["v"]

    history = []
    t0 = time.time()
    for step in range(1, train_cfg.steps + 1):
        src, tgt_in, tgt_out = stream.next_batch()
        lr = lr_schedule(step, train_cfg)
        loss, params, opt["m"], opt["v"] = step_fn(
            params, opt["m"], opt["v"], opt["t"], lr, src, tgt_in, tgt_out
        )
        opt["t"] += 1
        if step % log_every == 0 or step == 1:
            loss_f = float(loss)
            history.append({"step": step, "loss": loss_f, "lr": lr,
                            "elapsed_s": round(time.time() - t0, 1)})
            log(f"step {step:5d}  loss {loss_f:.4f}  lr {lr:.2e}  "
                f"({time.time() - t0:.0f}s)")
    return params, history
