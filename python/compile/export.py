"""Artifact writers: weights.bin + manifest.json + JSON helpers.

The Rust side (rust/src/model/weights.rs, rust/src/runtime/artifacts.rs)
parses exactly these formats:

* ``weights.bin``   — all parameter tensors, f32 little-endian, padded
                      to no alignment, concatenated in manifest order;
* ``manifest.json`` — [{"name", "shape", "offset"}] with offset in f32
                      elements into weights.bin;
* everything else   — plain JSON (config.json, calibration.json,
                      dataset.json, hlo_index.json).
"""

import json
import os

import numpy as np


def write_weights(params: dict, out_dir: str):
    names = sorted(params.keys())
    manifest = []
    offset = 0
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for name in names:
            arr = np.asarray(params[name], dtype=np.float32)
            manifest.append(
                {"name": name, "shape": list(arr.shape), "offset": offset}
            )
            f.write(arr.tobytes())  # C-order little-endian
            offset += arr.size
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump({"dtype": "f32", "total": offset, "tensors": manifest}, f, indent=1)
    return offset


def load_weights(out_dir: str):
    """Inverse of write_weights (used by aot.py to resume without retraining)."""
    with open(os.path.join(out_dir, "manifest.json")) as f:
        manifest = json.load(f)
    flat = np.fromfile(os.path.join(out_dir, "weights.bin"), dtype="<f4")
    params = {}
    for t in manifest["tensors"]:
        n = int(np.prod(t["shape"])) if t["shape"] else 1
        params[t["name"]] = flat[t["offset"] : t["offset"] + n].reshape(t["shape"])
    return params


def write_json(obj, out_dir: str, name: str):
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(obj, f)
