"""Corpus BLEU over token-id sequences (mirrors rust/src/data/bleu.rs).

Standard BLEU-4: modified n-gram precision with clipping, geometric
mean, brevity penalty.  Operates on token ids (the paper's BLEU is over
tokenized text; ours is over subword ids, which is equivalent for a
synthetic language).
"""

import math
from collections import Counter


def ngrams(seq, n):
    return Counter(tuple(seq[i : i + n]) for i in range(len(seq) - n + 1))


def corpus_bleu(hyps, refs, max_n: int = 4) -> float:
    """hyps/refs: lists of token-id lists (without EOS/PAD). Returns 0..100."""
    assert len(hyps) == len(refs)
    clipped = [0] * max_n
    total = [0] * max_n
    hyp_len = ref_len = 0
    for hyp, ref in zip(hyps, refs):
        hyp_len += len(hyp)
        ref_len += len(ref)
        for n in range(1, max_n + 1):
            h, r = ngrams(hyp, n), ngrams(ref, n)
            total[n - 1] += max(len(hyp) - n + 1, 0)
            clipped[n - 1] += sum(min(c, r[g]) for g, c in h.items())
    if min(total) == 0 or min(clipped) == 0:
        return 0.0
    log_p = sum(math.log(clipped[i] / total[i]) for i in range(max_n)) / max_n
    bp = 1.0 if hyp_len > ref_len else math.exp(1.0 - ref_len / max(hyp_len, 1))
    return 100.0 * bp * math.exp(log_p)


def strip_special(ids, eos_id: int, pad_id: int):
    """Truncate at first EOS and drop PADs."""
    out = []
    for t in ids:
        if t == eos_id:
            break
        if t != pad_id:
            out.append(int(t))
    return out
