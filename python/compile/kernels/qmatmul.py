"""Layer-1 Pallas kernels: the quantized-GEMM hot-spot of the paper.

The paper's hot-spot is the VNNI ``QuantizedMatMul`` (s8 x u8 -> s32 with
a float requantization epilogue).  On TPU the analogous structure is a
tiled MXU matmul whose operand tiles live in VMEM; ``BlockSpec`` below
expresses the HBM->VMEM schedule that the paper expressed with
register/cache blocking on Cascade Lake.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode (which lowers to
plain HLO) is the correctness path; TPU efficiency is *estimated* from
the BlockSpec footprint in DESIGN.md §Perf.

Kernels:

* ``quantize_s8_pallas``   — FP32 -> s8 with a given scale (eq. 5)
* ``dequantize_s8_pallas`` — s8  -> FP32 (eq. 6)
* ``qmatmul_pallas``       — s8 x u8 -> f32 tiled GEMM with i32
                             accumulation and zero-point corrections
* ``matmul_pallas``        — f32 tiled GEMM (the FP32 baseline)
* ``fake_quant_matmul``    — quantize -> qmatmul fusion used by model.py

Semantics are pinned by kernels/ref.py; python/tests/test_kernels.py
sweeps shapes and scales with hypothesis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import UINT8_ZERO_POINT


def _grid_dim(total: int, block: int) -> int:
    return (total + block - 1) // block


# --------------------------------------------------------------------------
# element-wise quantize / dequantize
# --------------------------------------------------------------------------

def _quantize_s8_kernel(x_ref, o_ref, *, inv_scale, zero_point):
    x = x_ref[...]
    q = jnp.round(x * inv_scale) + zero_point
    o_ref[...] = jnp.clip(q, -128, 127).astype(jnp.int8)


def quantize_s8_pallas(x, scale: float, zero_point: int = 0, block: int = 512):
    """FP32 -> s8 (paper eq. 5), tiled along the flattened dimension.

    The O(N) cost of this operation is exactly the "quantization
    overhead" the paper's §4.1/§5.5 work to minimize.
    """
    orig_shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    out = pl.pallas_call(
        functools.partial(
            _quantize_s8_kernel, inv_scale=1.0 / scale, zero_point=zero_point
        ),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.int8),
        grid=(_grid_dim(flat.shape[0], block),),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(flat)
    return out[:n].reshape(orig_shape)


def _dequantize_s8_kernel(q_ref, o_ref, *, scale, zero_point):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = (q - zero_point) * scale


def dequantize_s8_pallas(q, scale: float, zero_point: int = 0, block: int = 512):
    """s8 -> FP32 (paper eq. 6), tiled along the flattened dimension."""
    orig_shape = q.shape
    flat = q.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    out = pl.pallas_call(
        functools.partial(_dequantize_s8_kernel, scale=scale, zero_point=zero_point),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        grid=(_grid_dim(flat.shape[0], block),),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(flat)
    return out[:n].reshape(orig_shape)


# --------------------------------------------------------------------------
# quantized GEMM
# --------------------------------------------------------------------------

def _qmatmul_kernel(a_ref, b_ref, o_ref, *, za):
    """One (bm, bn) output tile; the k grid axis accumulates into o_ref.

    VMEM budget per step: bm*bk (s8) + bk*bn (u8) + bm*bn*4 (i32 out
    tile) — the BlockSpec schedule that stands in for the paper's cache
    blocking.  Both zero-point corrections are folded per k-block::

        sum (a - za)(b - 128)
      = sum a*b - 128*rowsum(a) - za*colsum(b) + za*128*bk
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)            # [bm, bk] s8 -> i32
    b = b_ref[...].astype(jnp.int32)            # [bk, bn] u8 -> i32
    acc = jnp.dot(a, b, preferred_element_type=jnp.int32)
    rowsum = jnp.sum(a, axis=1, keepdims=True)
    colsum = jnp.sum(b, axis=0, keepdims=True)
    bk = a.shape[1]
    o_ref[...] += (
        acc - UINT8_ZERO_POINT * rowsum - za * colsum + za * UINT8_ZERO_POINT * bk
    )


def qmatmul_i32_pallas(a_q, b_q, za: int = 0, bm: int = 32, bn: int = 64, bk: int = 64):
    """Integer core: s8 [M,K] x u8 [K,N] -> zero-point-corrected i32 [M,N].

    K padding uses a_pad=0 / b_pad=128 which contribute
    ``(0 - za)*(128 - 128) = 0`` to every corrected product, so padded
    and unpadded results agree exactly.
    """
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2, (a_q.shape, b_q.shape)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        a_q = jnp.pad(a_q, ((0, pm), (0, pk)))
    if pk or pn:
        b_q = jnp.pad(b_q, ((0, pk), (0, pn)), constant_values=UINT8_ZERO_POINT)
    gm, gn, gk = a_q.shape[0] // bm, b_q.shape[1] // bn, a_q.shape[1] // bk

    acc = pl.pallas_call(
        functools.partial(_qmatmul_kernel, za=za),
        out_shape=jax.ShapeDtypeStruct((a_q.shape[0], b_q.shape[1]), jnp.int32),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=True,
    )(a_q, b_q)
    return acc[:m, :n]


def qmatmul_pallas(a_q, b_q, sa: float, sb: float, za: int = 0, **blocks):
    """Tiled s8 x u8 -> f32 GEMM matching ``ref.qmatmul_ref`` exactly.

    The float epilogue (one multiply by sa*sb) is left to XLA to fuse —
    mirroring the paper's §5.5 optimization of dequantizing the INT32
    accumulator directly to FP32 instead of requantizing first.
    """
    acc = qmatmul_i32_pallas(a_q, b_q, za=za, **blocks)
    return acc.astype(jnp.float32) * (sa * sb)


def _matmul_kernel(a_ref, b_ref, o_ref):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def matmul_pallas(a, b, bm: int = 32, bn: int = 64, bk: int = 64):
    """Tiled f32 GEMM — the FP32 baseline the paper compares against."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    gm, gn, gk = a.shape[0] // bm, b.shape[1] // bn, a.shape[1] // bk
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], b.shape[1]), jnp.float32),
        interpret=True,
    )(a, b)
    return out[:m, :n]


def quantize_u8_weights(b, scale: float):
    """AOT-time weight quantization: f32 -> u8 with zero point 128."""
    q = jnp.round(b / scale) + UINT8_ZERO_POINT
    return jnp.clip(q, 0, 255).astype(jnp.uint8)


def fake_quant_matmul(a, b, a_scale: float, b_scale: float, a_zero: int = 0, **blocks):
    """float A x float B through the full int8 path (quantize -> qmatmul).

    This is what model.py inserts at every quantized MatMul site: the A
    quantization happens at run time (it is an activation), the B
    quantization folds into the AOT graph as a constant because B is a
    weight (the §5.5 "thresholds become Const" optimization).
    """
    a2 = a.reshape(-1, a.shape[-1])
    a_q = quantize_s8_pallas(a2, a_scale, a_zero)
    b_q = quantize_u8_weights(b, b_scale)
    out = qmatmul_pallas(a_q, b_q, a_scale, b_scale, a_zero, **blocks)
    return out.reshape(*a.shape[:-1], b.shape[-1])
