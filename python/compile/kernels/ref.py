"""Pure-jnp reference oracle for the quantization kernels.

This is the single source of truth for *numerics*: the Pallas kernels
(qmatmul.py), the JAX quantized model (model.py) and the Rust engine
(rust/src/quant, rust/src/gemm) are all tested against the semantics
defined here.

Quantization scheme (matches the paper §4 / §5.2 and MKL's s8*u8->s32
GEMM contract):

* the A operand (activation) is quantized to **signed** int8 with an
  affine map ``a_q = clip(round(a / sa) + za, -128, 127)``; for the
  symmetric/conjugate calibration modes ``za == 0``.
* the B operand (weight) is quantized to **unsigned** uint8 as
  ``b_q = clip(round(b / sb) + 128, 0, 255)`` — i.e. symmetric signed
  int8 shifted by the fixed zero point 128 (common MKL/oneDNN trick the
  paper alludes to when it says one tensor must be made unsigned).
* the product accumulates in int32; the float result is recovered as
  ``sa * sb * (acc - corrections)`` where the corrections remove the two
  zero points (the za correction needs the column sums of B_q, the 128
  correction needs the row sums of A_q).
"""

import jax.numpy as jnp

from ..common import UINT8_ZERO_POINT


def quantize_s8(a, scale, zero_point=0):
    """FP32 -> signed int8 (paper eq. 5). ``scale`` is the quantization step."""
    q = jnp.round(a / scale) + zero_point
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def quantize_u8(b, scale):
    """FP32 -> unsigned uint8 with fixed zero point 128."""
    q = jnp.round(b / scale) + UINT8_ZERO_POINT
    return jnp.clip(q, 0, 255).astype(jnp.uint8)


def dequantize_s8(q, scale, zero_point=0):
    """Signed int8 -> FP32 (paper eq. 6)."""
    return (q.astype(jnp.float32) - zero_point) * scale


def qmatmul_ref(a_q, b_q, sa, sb, za=0):
    """int8 x uint8 -> fp32 reference GEMM.

    a_q: [M, K] int8, b_q: [K, N] uint8 (zero point 128), accumulate i32::

        acc[m,n]   = sum_k a_q[m,k] * b_q[k,n]
        rowsum[m]  = sum_k a_q[m,k]
        colsum[n]  = sum_k b_q[k,n]
        out[m,n]   = sa*sb * (acc - 128*rowsum[m] - za*colsum[n]
                              + K*za*128)
    """
    a32 = a_q.astype(jnp.int32)
    b32 = b_q.astype(jnp.int32)
    k = a_q.shape[-1]
    acc = a32 @ b32
    rowsum = jnp.sum(a32, axis=-1, keepdims=True)          # [M, 1]
    colsum = jnp.sum(b32, axis=-2, keepdims=True)          # [1, N]
    acc = acc - UINT8_ZERO_POINT * rowsum - za * colsum + k * za * UINT8_ZERO_POINT
    return acc.astype(jnp.float32) * (sa * sb)


def fake_quant_matmul_ref(a, b, a_scale, b_scale, a_zero=0):
    """End-to-end float->int8->GEMM->float reference used by the model."""
    a_q = quantize_s8(a, a_scale, a_zero)
    b_q = quantize_u8(b, b_scale)
    return qmatmul_ref(a_q, b_q, a_scale, b_scale, a_zero)


def matmul_ref(a, b):
    return jnp.matmul(a, b)
