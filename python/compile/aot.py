"""AOT compile path: dataset -> train -> calibrate -> export -> HLO text.

Run as ``python -m compile.aot --out ../artifacts`` (the Makefile's
``make artifacts``).  Python never runs again after this: the Rust
coordinator loads the HLO executables via PJRT and the Rust engine loads
weights.bin directly.

HLO **text** (not ``.serialize()``) is the interchange format: jax>=0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension
0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Exports into --out:

  dataset.json       lexicon + valid/test splits + calibration indices
  weights.bin        trained FP32 parameters (f32 LE)
  manifest.json      tensor name/shape/offset index into weights.bin
  calibration.json   per-site histogram class + KL thresholds (all modes)
  config.json        every constant the Rust side must agree on
  train_log.json     loss curve (EXPERIMENTS.md provenance)
  translate_{prec}_b{B}.hlo.txt   greedy-translate executables
  hlo_index.json     bucket -> file map for runtime::artifacts
"""

import argparse
import json
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .common import (
    AotConfig,
    DataConfig,
    ModelConfig,
    TrainConfig,
    config_dict,
    EOS_ID,
    PAD_ID,
)
from . import calibrate as C
from . import datagen
from . import export
from . import model as M
from . import train as T
from .bleu import corpus_bleu, strip_special


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the only proto-safe path).

    ``print_large_constants=True`` is essential: the default printer
    elides big literals as ``constant({...})`` and the downstream text
    parser silently zero-fills them — the baked-in trained weights would
    arrive in Rust as all-zeros.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions.short_parsable()
    opts.print_large_constants = True
    return comp.as_hlo_module().to_string(opts)


def lower_translate(params, cfg: ModelConfig, qctx, batch: int, src_len: int,
                    tgt_len: int):
    """Close over weights (-> HLO constants) and lower translate_greedy."""

    def fn(src_ids):
        out, lengths = M.translate_greedy(params, cfg, src_ids, qctx=qctx,
                                          max_len=tgt_len)
        return out, lengths

    spec = jax.ShapeDtypeStruct((batch, src_len), jnp.int32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def quick_bleu(params, cfg, pairs, qctx=None, batch: int = 64, limit: int = 256):
    """Greedy-translate a subset and score corpus BLEU (sanity signal)."""
    pairs = pairs[:limit]
    jit_fn = jax.jit(
        lambda s: M.translate_greedy(params, cfg, s, qctx=qctx,
                                     max_len=cfg.max_tgt_len)
    )
    hyps, refs = [], []
    for i in range(0, len(pairs), batch):
        chunk = pairs[i : i + batch]
        src = datagen.pad_batch([p["src"] for p in chunk], cfg.max_src_len)
        out, _ = jit_fn(src)
        out = np.asarray(out)
        for row, p in zip(out, chunk):
            hyps.append(strip_special(row.tolist(), EOS_ID, PAD_ID))
            refs.append(strip_special(p["ref"], EOS_ID, PAD_ID))
    return corpus_bleu(hyps, refs)


def lower_all(out, params, model_cfg, aot_cfg, qctx):
    """Lower fp32 + int8 executables for every batch bucket."""
    index = {"buckets": [], "src_len": aot_cfg.src_bucket,
             "tgt_len": aot_cfg.tgt_bucket}
    for batch in aot_cfg.batch_buckets:
        for prec, ctx in (("fp32", None), ("int8", qctx)):
            name = f"translate_{prec}_b{batch}.hlo.txt"
            t1 = time.time()
            text = lower_translate(params, model_cfg, ctx, batch,
                                   aot_cfg.src_bucket, aot_cfg.tgt_bucket)
            with open(os.path.join(out, name), "w") as f:
                f.write(text)
            index["buckets"].append(
                {"file": name, "precision": prec, "batch": batch,
                 "src_len": aot_cfg.src_bucket, "tgt_len": aot_cfg.tgt_bucket}
            )
            print(f"   {name}: {len(text)} chars ({time.time() - t1:.0f}s)")
    export.write_json(index, out, "hlo_index.json")


def hlo_only(out):
    """Re-lower executables from existing weights + calibration."""
    model_cfg = ModelConfig()
    aot_cfg = AotConfig()
    params = {k: jnp.asarray(v) for k, v in export.load_weights(out).items()}
    cals, wscales = C.load_calibration(os.path.join(out, "calibration.json"))
    table = C.build_site_table(model_cfg, cals, wscales, "symmetric")
    qctx = M.make_qctx(table)
    print("== HLO lowering (hlo-only) ==")
    lower_all(out, params, model_cfg, aot_cfg, qctx)
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=None, help="override train steps")
    ap.add_argument("--force", action="store_true", help="rebuild everything")
    ap.add_argument("--skip-hlo", action="store_true",
                    help="stop after calibration export (tests use this)")
    ap.add_argument("--hlo-only", action="store_true",
                    help="re-lower HLO from existing weights+calibration")
    args = ap.parse_args(argv)
    out = args.out
    os.makedirs(out, exist_ok=True)

    stamp = os.path.join(out, ".complete")
    if args.hlo_only:
        return hlo_only(out)
    if os.path.exists(stamp) and not args.force:
        print("artifacts up to date (use --force to rebuild)")
        return 0

    model_cfg = ModelConfig()
    data_cfg = DataConfig()
    train_cfg = TrainConfig()
    aot_cfg = AotConfig()
    if args.steps is not None:
        train_cfg.steps = args.steps

    t0 = time.time()
    print("== dataset ==")
    splits = datagen.export_splits(data_cfg, model_cfg)
    export.write_json(splits, out, "dataset.json")
    print(f"   valid={len(splits['valid'])} test={len(splits['test'])} "
          f"calib={len(splits['calibration_indices'])}")

    print("== train ==")
    have_weights = (
        os.path.exists(os.path.join(out, "weights.bin"))
        and os.path.exists(os.path.join(out, "manifest.json"))
        and not args.force
    )
    if have_weights:
        print("   reusing existing weights.bin")
        params = {k: jnp.asarray(v) for k, v in export.load_weights(out).items()}
        history = []
    else:
        params, history = T.train(model_cfg, data_cfg, train_cfg)
        export.write_weights({k: np.asarray(v) for k, v in params.items()}, out)
    export.write_json(history, out, "train_log.json")

    print("== fp32 sanity BLEU ==")
    bleu_fp32 = quick_bleu(params, model_cfg, splits["test"])
    print(f"   fp32 BLEU (256-sentence subset) = {bleu_fp32:.2f}")

    print("== calibration ==")
    calib_pairs = [splits["valid"][i] for i in splits["calibration_indices"]]
    cals = C.calibrate_model(params, model_cfg, calib_pairs)
    wscales = C.weight_scales(params, model_cfg)
    export.write_json(
        {
            "sites": {k: v.to_dict() for k, v in cals.items()},
            "weight_scales": wscales,
        },
        out,
        "calibration.json",
    )

    print("== config ==")
    cfgd = config_dict()
    cfgd["fp32_bleu_subset"] = bleu_fp32
    export.write_json(cfgd, out, "config.json")

    if args.skip_hlo:
        print("== skipping HLO lowering (--skip-hlo) ==")
        return 0

    print("== int8 sanity BLEU (symmetric) ==")
    table = C.build_site_table(model_cfg, cals, wscales, "symmetric")
    qctx = M.make_qctx(table)
    bleu_int8 = quick_bleu(params, model_cfg, splits["test"], qctx=qctx)
    print(f"   int8 BLEU (256-sentence subset) = {bleu_int8:.2f}")

    print("== HLO lowering ==")
    lower_all(out, params, model_cfg, aot_cfg, qctx)

    with open(stamp, "w") as f:
        f.write(f"built in {time.time() - t0:.0f}s\n")
    print(f"== done in {time.time() - t0:.0f}s ==")
    return 0


if __name__ == "__main__":
    sys.exit(main())
