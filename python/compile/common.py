"""Shared configuration for the quantnmt compile path.

Everything the Rust side needs to agree on (special token ids, model
dimensions, dataset sizes, quantization constants) is defined here and
exported into ``artifacts/`` by ``aot.py`` so the two halves can never
drift silently.
"""

from dataclasses import dataclass, field, asdict

# --- special tokens (mirrored in rust/src/data/vocab.rs) -------------------
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
FIRST_CONTENT_ID = 3

# --- quantization constants (mirrored in rust/src/quant/scheme.rs) ---------
HIST_BINS = 2048          # calibration histogram resolution
QUANT_BINS = 128          # target int8 positive range used by KL calibration
INT8_MAX = 127.0
UINT8_ZERO_POINT = 128    # u8 zero point used for the B operand (weights)


@dataclass
class ModelConfig:
    """Transformer-base-shaped (scaled down) encoder-decoder config.

    The paper quantizes the Transformer *base* model (d_model=512, 6+6
    layers, 8 heads).  We keep the exact architecture — post-LN residual
    blocks, scaled dot-product multi-head attention, learned embeddings
    shared with the output projection — at a size a CPU can train in
    minutes.  All the quantization phenomena of interest (long-tailed
    activations, sparse ReLU tensors, Softmax/LayerNorm precision
    sensitivity) are present at this scale.
    """

    vocab_size: int = 96
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 256
    n_enc_layers: int = 2
    n_dec_layers: int = 2
    max_src_len: int = 64
    max_tgt_len: int = 64
    dropout: float = 0.0          # inference-focused repro; no dropout

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


@dataclass
class DataConfig:
    """Synthetic parallel-corpus config (see datagen.py).

    newstest2014 has 3003 sentences; the paper calibrates on 600 random
    validation sentences.  We mirror both counts exactly.
    """

    n_words: int = 256            # word lexicon size
    min_words: int = 3            # words per sentence
    max_words: int = 12
    min_spell: int = 1            # subword tokens per word
    max_spell: int = 4
    zipf_s: float = 1.1           # word frequency skew (natural-language-ish)
    n_valid: int = 3003
    n_test: int = 3003
    n_calibration: int = 600
    seed: int = 20190610          # paper's workshop date


@dataclass
class TrainConfig:
    batch_size: int = 64
    steps: int = 1000
    warmup: int = 200
    peak_lr: float = 3e-3
    label_smoothing: float = 0.0
    seed: int = 7


@dataclass
class AotConfig:
    """Which (batch, src_len) buckets get AOT-compiled executables.

    PJRT executables are static-shaped; the Rust runtime picks the
    smallest bucket that fits a batch (pipeline::padding handles the
    padding).  The paper uses mini-batch 64 throughout §6.
    """

    batch_buckets: tuple = (1, 16, 64)
    src_bucket: int = 48          # fits p99 of the synthetic corpus
    tgt_bucket: int = 56


def config_dict():
    return {
        "pad_id": PAD_ID,
        "bos_id": BOS_ID,
        "eos_id": EOS_ID,
        "hist_bins": HIST_BINS,
        "int8_max": INT8_MAX,
        "uint8_zero_point": UINT8_ZERO_POINT,
        "model": asdict(ModelConfig()),
        "data": asdict(DataConfig()),
        "train": asdict(TrainConfig()),
        "aot": {
            "batch_buckets": list(AotConfig().batch_buckets),
            "src_bucket": AotConfig().src_bucket,
            "tgt_bucket": AotConfig().tgt_bucket,
        },
    }
