"""Layer-2: the Transformer NMT model in JAX (fwd + greedy decode).

Architecture = Vaswani et al. scaled down (see common.ModelConfig):
post-LN residual blocks, sinusoidal positions, multi-head scaled
dot-product attention, ReLU FFN, tied input/output embeddings.

Every MatMul in the network goes through ``_mm`` which consults an
optional *quantization context* mapping site names to calibrated
thresholds.  This is the JAX analogue of the paper's TensorFlow graph
transform (Fig 1 -> Fig 5): with ``qctx=None`` the graph is the FP32
original; with a context, selected MatMuls are rewritten into
quantize -> int8 GEMM -> dequantize with **constant** thresholds (the
§5.5 "thresholds become Const nodes" optimization — no Min/Max ops in
the lowered HLO).

Shape-aware kernel choice (§5.2): encoder weight MatMuls have large M
(= batch * seq) and use the Pallas tiled kernel (kernels/qmatmul.py);
decoder per-step MatMuls have M = batch and attention tensor x tensor
MatMuls are batched per head, so they use the pure-jnp int8 emulation
(kernels/ref.py) with identical numerics — quantizing them all, as the
paper does, while matching kernel shape to matrix shape.

The auto-regressive greedy decode is a ``lax.while_loop`` with a
statically-shaped KV cache, so the whole translate function lowers to a
single HLO executable (runtime/ loads it from Rust).
"""

import math

import jax
import jax.numpy as jnp
from jax import lax

from .common import BOS_ID, EOS_ID, PAD_ID, ModelConfig
from .kernels import qmatmul as pk
from .kernels import ref as kref

NEG_INF = -1e9


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    """Xavier-ish init; returns a flat dict name -> array.

    Names are the contract with the Rust engine (model::weights) and the
    calibration table; do not rename without bumping both.
    """
    params = {}

    def dense(k, shape):
        fan_in = shape[0]
        return jax.random.normal(k, shape, jnp.float32) / math.sqrt(fan_in)

    keys = iter(jax.random.split(key, 1024))
    params["embed"] = jax.random.normal(next(keys), (cfg.vocab_size, cfg.d_model)) * 0.02

    def attn_block(prefix):
        for w in ("wq", "wk", "wv", "wo"):
            params[f"{prefix}.{w}"] = dense(next(keys), (cfg.d_model, cfg.d_model))

    def ln_block(prefix):
        params[f"{prefix}.gamma"] = jnp.ones((cfg.d_model,))
        params[f"{prefix}.beta"] = jnp.zeros((cfg.d_model,))

    def ffn_block(prefix):
        params[f"{prefix}.w1"] = dense(next(keys), (cfg.d_model, cfg.d_ff))
        params[f"{prefix}.b1"] = jnp.zeros((cfg.d_ff,))
        params[f"{prefix}.w2"] = dense(next(keys), (cfg.d_ff, cfg.d_model))
        params[f"{prefix}.b2"] = jnp.zeros((cfg.d_model,))

    for i in range(cfg.n_enc_layers):
        attn_block(f"enc.{i}.attn")
        ln_block(f"enc.{i}.ln1")
        ffn_block(f"enc.{i}.ffn")
        ln_block(f"enc.{i}.ln2")
    for i in range(cfg.n_dec_layers):
        attn_block(f"dec.{i}.self")
        ln_block(f"dec.{i}.ln1")
        attn_block(f"dec.{i}.cross")
        ln_block(f"dec.{i}.ln2")
        ffn_block(f"dec.{i}.ffn")
        ln_block(f"dec.{i}.ln3")
    return params


def positional_encoding(max_len: int, d_model: int):
    """Sinusoidal positions, identical formula in rust model::embedding."""
    pos = jnp.arange(max_len)[:, None].astype(jnp.float32)
    i = jnp.arange(d_model // 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10000.0, 2.0 * i / d_model)
    pe = jnp.zeros((max_len, d_model))
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# --------------------------------------------------------------------------
# quantization-aware matmul dispatch
# --------------------------------------------------------------------------

class QuantSite:
    """Calibrated thresholds for one MatMul site.

    a_scale/a_zero quantize the A (activation) operand to s8; b_scale
    quantizes the B operand to u8 (zero point 128).  For weight sites,
    b_scale comes from the weight's own |max|; for dynamic sites (QK^T,
    attn x V) it comes from activation calibration of the B side.
    """

    __slots__ = ("a_scale", "a_zero", "b_scale")

    def __init__(self, a_scale, a_zero, b_scale):
        self.a_scale = float(a_scale)
        self.a_zero = int(a_zero)
        self.b_scale = float(b_scale)


def _mm(site: str, a, b, qctx, collect=None, pallas_ok=False):
    """MatMul with optional quantization and calibration hooks.

    collect(site_side, tensor) feeds the calibration histogram pass.
    pallas_ok selects the Pallas tiled kernel for 2D large-M sites.
    """
    if collect is not None:
        collect(site + ".a", a)
        collect(site + ".b", b)
    q = None if qctx is None else qctx.get(site)
    if q is None:
        return jnp.matmul(a, b)
    if pallas_ok and a.ndim == 2 and b.ndim == 2:
        return pk.fake_quant_matmul(a, b, q.a_scale, q.b_scale, q.a_zero)
    return kref.fake_quant_matmul_ref(a, b, q.a_scale, q.b_scale, q.a_zero)


def _dense(site, x, w, qctx, collect=None, pallas_ok=True):
    """x [..., D_in] @ w [D_in, D_out] through a 2D reshape."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _mm(site, x2, w, qctx, collect, pallas_ok=pallas_ok)
    return y.reshape(*lead, w.shape[-1])


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------

def layer_norm(x, gamma, beta, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def _split_heads(x, n_heads):
    b, t, d = x.shape
    return x.reshape(b, t, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def attention_core(prefix, qh, kh, vh, mask, cfg, qctx, collect=None):
    """scores = QK^T/sqrt(dk) -> softmax (always FP32, §3) -> @V.

    Both tensor x tensor MatMuls are quantization sites ("both inputs
    signed FP32" in the paper's words).
    """
    scores = _mm(f"{prefix}.qk", qh, kh.transpose(0, 1, 3, 2), qctx, collect)
    scores = scores / math.sqrt(cfg.d_head)
    if mask is not None:
        scores = scores + mask
    probs = jax.nn.softmax(scores, axis=-1)          # FP32 on purpose
    return _mm(f"{prefix}.pv", probs, vh, qctx, collect)


def mha(prefix, params, cfg: ModelConfig, q_in, kv_in, mask, qctx, collect=None,
        pallas_ok=True):
    """Multi-head attention (paper eq. 1-2). mask: [B,1,Tq,Tk] additive."""
    q = _dense(f"{prefix}.q", q_in, params[f"{prefix}.wq"], qctx, collect, pallas_ok)
    k = _dense(f"{prefix}.k", kv_in, params[f"{prefix}.wk"], qctx, collect, pallas_ok)
    v = _dense(f"{prefix}.v", kv_in, params[f"{prefix}.wv"], qctx, collect, pallas_ok)
    qh, kh, vh = (_split_heads(t, cfg.n_heads) for t in (q, k, v))
    ctx = attention_core(prefix, qh, kh, vh, mask, cfg, qctx, collect)
    return _dense(f"{prefix}.o", _merge_heads(ctx), params[f"{prefix}.wo"], qctx,
                  collect, pallas_ok)


def ffn(prefix, params, x, qctx, collect=None, pallas_ok=True):
    h = _dense(f"{prefix}.h", x, params[f"{prefix}.w1"], qctx, collect, pallas_ok)
    h = jax.nn.relu(h + params[f"{prefix}.b1"])
    # post-ReLU input: the paper's canonical *sparse* histogram (Fig 2);
    # calibration normally leaves this site unquantized.
    y = _dense(f"{prefix}.y", h, params[f"{prefix}.w2"], qctx, collect, pallas_ok)
    return y + params[f"{prefix}.b2"]


def _ln(prefix, params, x):
    return layer_norm(x, params[f"{prefix}.gamma"], params[f"{prefix}.beta"])


# --------------------------------------------------------------------------
# encoder / decoder
# --------------------------------------------------------------------------

def src_pad_mask(src_ids):
    """[B,1,1,S] additive mask hiding PAD positions."""
    is_pad = (src_ids == PAD_ID)[:, None, None, :]
    return jnp.where(is_pad, NEG_INF, 0.0)


def embed(params, cfg, ids):
    pe = positional_encoding(max(cfg.max_src_len, cfg.max_tgt_len), cfg.d_model)
    x = params["embed"][ids] * math.sqrt(cfg.d_model)
    return x + pe[: ids.shape[1]]


def encode(params, cfg: ModelConfig, src_ids, qctx=None, collect=None):
    """src token ids [B,S] -> memory [B,S,D]."""
    mask = src_pad_mask(src_ids)
    x = embed(params, cfg, src_ids)
    for i in range(cfg.n_enc_layers):
        p = f"enc.{i}"
        a = mha(f"{p}.attn", params, cfg, x, x, mask, qctx, collect, pallas_ok=True)
        x = _ln(f"{p}.ln1", params, x + a)
        f = ffn(f"{p}.ffn", params, x, qctx, collect, pallas_ok=True)
        x = _ln(f"{p}.ln2", params, x + f)
    return x


def decode_train(params, cfg: ModelConfig, memory, src_ids, tgt_in,
                 qctx=None, collect=None):
    """Teacher-forced decoder: tgt_in [B,T] -> logits [B,T,V].

    Used for training, calibration collection, and logit-parity tests.
    Decoder sites use pallas_ok=False (jnp int8 emulation) to match the
    per-step decode graph numerics exactly.
    """
    b, t = tgt_in.shape
    causal = jnp.where(
        jnp.arange(t)[None, :] > jnp.arange(t)[:, None], NEG_INF, 0.0
    )[None, None, :, :]
    mem_mask = src_pad_mask(src_ids)
    x = embed(params, cfg, tgt_in)
    for i in range(cfg.n_dec_layers):
        p = f"dec.{i}"
        a = mha(f"{p}.self", params, cfg, x, x, causal, qctx, collect, pallas_ok=False)
        x = _ln(f"{p}.ln1", params, x + a)
        c = mha(f"{p}.cross", params, cfg, x, memory, mem_mask, qctx, collect,
                pallas_ok=False)
        x = _ln(f"{p}.ln2", params, x + c)
        f = ffn(f"{p}.ffn", params, x, qctx, collect, pallas_ok=False)
        x = _ln(f"{p}.ln3", params, x + f)
    return _dense("logits", x, params["embed"].T, qctx, collect, pallas_ok=False)


def forward_teacher(params, cfg, src_ids, tgt_in, qctx=None, collect=None):
    memory = encode(params, cfg, src_ids, qctx, collect)
    return decode_train(params, cfg, memory, src_ids, tgt_in, qctx, collect)


# --------------------------------------------------------------------------
# greedy auto-regressive decode (lowers to one HLO while-loop)
# --------------------------------------------------------------------------

def _decode_step(params, cfg, qctx, memory, mem_mask, cache_k, cache_v, tok, pos):
    """One decoder step for tokens [B] at position ``pos``.

    cache_k/cache_v: [L, B, H, Tmax, dh] statically-shaped self-attention
    KV caches; this step's K/V are written at index ``pos`` (the
    dynamic-update that, together with the beam gather, is the paper's
    GatherNd territory, §5.3).
    """
    pe = positional_encoding(cfg.max_tgt_len, cfg.d_model)
    x = params["embed"][tok[:, None]] * math.sqrt(cfg.d_model)
    x = x + lax.dynamic_slice_in_dim(pe, pos, 1, axis=0)[None, 0:1, :].reshape(1, 1, -1)

    t_max = cache_k.shape[3]
    # causal-by-construction: attend only to cache positions <= pos
    step_mask = jnp.where(jnp.arange(t_max)[None, None, None, :] > pos, NEG_INF, 0.0)

    for i in range(cfg.n_dec_layers):
        p = f"dec.{i}"
        q = _dense(f"{p}.self.q", x, params[f"{p}.self.wq"], qctx, pallas_ok=False)
        k = _dense(f"{p}.self.k", x, params[f"{p}.self.wk"], qctx, pallas_ok=False)
        v = _dense(f"{p}.self.v", x, params[f"{p}.self.wv"], qctx, pallas_ok=False)
        kh = _split_heads(k, cfg.n_heads)            # [B,H,1,dh]
        vh = _split_heads(v, cfg.n_heads)
        cache_k = lax.dynamic_update_slice(cache_k, kh[None], (i, 0, 0, pos, 0))
        cache_v = lax.dynamic_update_slice(cache_v, vh[None], (i, 0, 0, pos, 0))
        qh = _split_heads(q, cfg.n_heads)
        ctx = attention_core(f"{p}.self", qh, cache_k[i], cache_v[i],
                             step_mask, cfg, qctx)
        a = _dense(f"{p}.self.o", _merge_heads(ctx), params[f"{p}.self.wo"],
                   qctx, pallas_ok=False)
        x = _ln(f"{p}.ln1", params, x + a)
        c = mha(f"{p}.cross", params, cfg, x, memory, mem_mask, qctx,
                pallas_ok=False)
        x = _ln(f"{p}.ln2", params, x + c)
        f = ffn(f"{p}.ffn", params, x, qctx, pallas_ok=False)
        x = _ln(f"{p}.ln3", params, x + f)

    logits = _dense("logits", x, params["embed"].T, qctx, pallas_ok=False)
    return logits[:, 0, :], cache_k, cache_v


def translate_greedy(params, cfg: ModelConfig, src_ids, qctx=None, max_len=None):
    """src [B,S] i32 -> (out [B,Tmax] i32, lengths [B] i32).

    Greedy decode inside lax.while_loop; stops early when every sentence
    has emitted EOS (the paper's "failed to emit a stop token" pathology
    for naive quantization shows up here as rows that never finish).
    """
    b = src_ids.shape[0]
    t_max = max_len or cfg.max_tgt_len
    memory = encode(params, cfg, src_ids, qctx)
    mem_mask = src_pad_mask(src_ids)
    cache_k = jnp.zeros((cfg.n_dec_layers, b, cfg.n_heads, t_max, cfg.d_head))
    cache_v = jnp.zeros_like(cache_k)
    out = jnp.full((b, t_max), PAD_ID, jnp.int32)
    tok = jnp.full((b,), BOS_ID, jnp.int32)
    fin = jnp.zeros((b,), jnp.bool_)

    def cond(state):
        pos, _, _, _, _, fin = state
        return jnp.logical_and(pos < t_max, jnp.logical_not(jnp.all(fin)))

    def body(state):
        pos, tok, out, ck, cv, fin = state
        logits, ck, cv = _decode_step(
            params, cfg, qctx, memory, mem_mask, ck, cv, tok, pos
        )
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        nxt = jnp.where(fin, PAD_ID, nxt)
        out = lax.dynamic_update_slice(out, nxt[:, None], (0, pos))
        fin = jnp.logical_or(fin, nxt == EOS_ID)
        return pos + 1, nxt, out, ck, cv, fin

    _, _, out, _, _, _ = lax.while_loop(
        cond, body, (jnp.int32(0), tok, out, cache_k, cache_v, fin)
    )
    lengths = jnp.sum(jnp.cumsum((out == EOS_ID).astype(jnp.int32), axis=1) == 0,
                      axis=1) + 1
    lengths = jnp.minimum(lengths, t_max)
    return out, lengths


# --------------------------------------------------------------------------
# loss (build-time training only)
# --------------------------------------------------------------------------

def loss_fn(params, cfg, src, tgt_in, tgt_out):
    logits = forward_teacher(params, cfg, src, tgt_in)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt_out[..., None], axis=-1)[..., 0]
    mask = (tgt_out != PAD_ID).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_qctx(site_table):
    """site_table: dict name -> (a_scale, a_zero, b_scale) or None."""
    return {
        k: (None if v is None else QuantSite(*v)) for k, v in site_table.items()
    }


def matmul_site_names(cfg: ModelConfig):
    """Every quantizable MatMul site in graph order (the paper's "97
    MatMuls" census for our model; used by calibration and the graph IR)."""
    sites = []
    for i in range(cfg.n_enc_layers):
        p = f"enc.{i}"
        sites += [f"{p}.attn.{s}" for s in ("q", "k", "v", "qk", "pv", "o")]
        sites += [f"{p}.ffn.h", f"{p}.ffn.y"]
    for i in range(cfg.n_dec_layers):
        p = f"dec.{i}"
        sites += [f"{p}.self.{s}" for s in ("q", "k", "v", "qk", "pv", "o")]
        sites += [f"{p}.cross.{s}" for s in ("q", "k", "v", "qk", "pv", "o")]
        sites += [f"{p}.ffn.h", f"{p}.ffn.y"]
    sites.append("logits")
    return sites


def weight_for_site(cfg: ModelConfig, site: str):
    """Weight-tensor name for a weight-MatMul site, or None if dynamic.

    ("logits" uses the tied embedding, transposed.)
    """
    if site == "logits":
        return "embed.T"
    head, leaf = site.rsplit(".", 1)
    if leaf in ("q", "k", "v", "o"):
        return f"{head}.w{leaf}"
    if leaf == "h":
        return f"{head}.w1"
    if leaf == "y":
        return f"{head}.w2"
    return None  # qk / pv are tensor x tensor
