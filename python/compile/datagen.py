"""Synthetic parallel corpus standing in for WMT'14 En->De / newstest2014.

The paper evaluates on the 3003-sentence newstest2014 set with a
Transformer trained on WMT.  We do not have WMT, so we build a synthetic
"language pair" that preserves every property the paper's experiments
exercise:

* **Words vs tokens.**  Sentences are sequences of *words* drawn from a
  Zipf-distributed lexicon; each word deterministically "spells" into
  1..4 *subword tokens*.  This makes word-count sorting and token-count
  sorting genuinely different orders (needed for the §5.4 +28% result).

* **Variable lengths.**  3..12 words => roughly 3..48 tokens, so batches
  have real padding waste and per-batch decode cost varies (needed for
  parallel batching, §5.6).

* **A learnable translation.**  The target is the *reversed* source token
  sequence mapped through a fixed permutation of the content vocabulary.
  Reversal forces the encoder-decoder attention to do real long-range
  work (a copy task would let the model ignore the encoder), while still
  being learnable to near-100 BLEU in ~1.5k steps — giving a crisp
  accuracy baseline to measure quantization drop against, exactly like
  the paper's 27.68 BLEU starting point.

Determinism: everything derives from DataConfig.seed via SplitMix64, so
the Rust side (rust/src/data/synthetic.rs) can regenerate identical
corpora for its own benches without reading the JSON exports.
"""

from dataclasses import dataclass

import numpy as np

from .common import (
    BOS_ID,
    EOS_ID,
    FIRST_CONTENT_ID,
    DataConfig,
    ModelConfig,
)

_MASK = (1 << 64) - 1


class SplitMix64:
    """Tiny deterministic PRNG, implemented identically in Rust.

    (numpy's Generators are not stable across versions and cannot be
    reimplemented compactly in Rust; SplitMix64 is 5 lines in both.)
    """

    def __init__(self, seed: int):
        self.state = seed & _MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & _MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4B9FD) & _MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
        return (z ^ (z >> 31)) & _MASK

    def below(self, n: int) -> int:
        """Uniform integer in [0, n) (modulo bias negligible for n << 2^64)."""
        return self.next_u64() % n

    def range(self, lo: int, hi: int) -> int:
        """Uniform integer in [lo, hi] inclusive."""
        return lo + self.below(hi - lo + 1)

    def f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))


_CONSONANTS = "bcdfghjklmnpqrstvwz"
_VOWELS = "aeiou"


@dataclass
class Lexicon:
    """Word lexicon: surface strings, subword spellings, Zipf weights."""

    words: list          # surface strings
    spellings: list      # list[list[int]] token ids per word
    cum_weights: np.ndarray  # cumulative Zipf probabilities

    @property
    def n_words(self) -> int:
        return len(self.words)


def content_vocab_size(model: ModelConfig) -> int:
    return model.vocab_size - FIRST_CONTENT_ID


def build_lexicon(data: DataConfig, model: ModelConfig) -> Lexicon:
    rng = SplitMix64(data.seed)
    n_content = content_vocab_size(model)
    words, spellings, seen = [], [], set()
    while len(words) < data.n_words:
        n_tok = rng.range(data.min_spell, data.max_spell)
        spelling = tuple(FIRST_CONTENT_ID + rng.below(n_content) for _ in range(n_tok))
        if spelling in seen:
            continue
        seen.add(spelling)
        # a pronounceable surface form derived from the spelling
        surf = "".join(
            _CONSONANTS[t % len(_CONSONANTS)] + _VOWELS[(t // 7) % len(_VOWELS)]
            for t in spelling
        )
        # disambiguate homographs deterministically
        if any(w == surf for w in words):
            surf = f"{surf}{len(words)}"
        words.append(surf)
        spellings.append(list(spelling))
    ranks = np.arange(1, data.n_words + 1, dtype=np.float64)
    w = ranks ** (-data.zipf_s)
    return Lexicon(words, spellings, np.cumsum(w / w.sum()))


def translation_permutation(data: DataConfig, model: ModelConfig) -> np.ndarray:
    """Fixed content-token permutation (Fisher-Yates under SplitMix64)."""
    rng = SplitMix64(data.seed ^ 0xABCDEF)
    n = content_vocab_size(model)
    perm = np.arange(n, dtype=np.int64)
    for i in range(n - 1, 0, -1):
        j = rng.below(i + 1)
        perm[i], perm[j] = perm[j], perm[i]
    return perm


@dataclass
class Pair:
    src: list       # token ids, EOS-terminated, no BOS
    ref: list       # token ids, EOS-terminated
    n_words: int    # word count of the source (for §5.4 word-sorting)
    text: str       # surface form of the source sentence


def translate_tokens(src_content: list, perm: np.ndarray) -> list:
    """Reference translation rule: reverse + permute content tokens."""
    return [int(perm[t - FIRST_CONTENT_ID]) + FIRST_CONTENT_ID for t in reversed(src_content)]


def sample_pair(rng: SplitMix64, lex: Lexicon, perm: np.ndarray, data: DataConfig) -> Pair:
    n_words = rng.range(data.min_words, data.max_words)
    idxs = [int(np.searchsorted(lex.cum_weights, rng.f64())) for _ in range(n_words)]
    idxs = [min(i, lex.n_words - 1) for i in idxs]
    src_content = [t for i in idxs for t in lex.spellings[i]]
    tgt_content = translate_tokens(src_content, perm)
    return Pair(
        src=src_content + [EOS_ID],
        ref=tgt_content + [EOS_ID],
        n_words=n_words,
        text=" ".join(lex.words[i] for i in idxs),
    )


def make_split(split_seed: int, n: int, lex: Lexicon, perm: np.ndarray, data: DataConfig):
    rng = SplitMix64(split_seed)
    return [sample_pair(rng, lex, perm, data) for _ in range(n)]


def pad_batch(seqs, max_len: int, pad=0, bos=False) -> np.ndarray:
    """Right-pad (optionally BOS-prefixed) sequences into an i32 [B, max_len]."""
    out = np.full((len(seqs), max_len), pad, dtype=np.int32)
    for r, s in enumerate(seqs):
        s = ([BOS_ID] + list(s)) if bos else list(s)
        s = s[:max_len]
        out[r, : len(s)] = s
    return out


class TrainStream:
    """Infinite stream of padded training batches (teacher forcing)."""

    def __init__(self, data: DataConfig, model: ModelConfig, batch: int, seed: int):
        self.lex = build_lexicon(data, model)
        self.perm = translation_permutation(data, model)
        self.rng = SplitMix64(seed)
        self.data, self.model, self.batch = data, model, batch

    def next_batch(self):
        pairs = [sample_pair(self.rng, self.lex, self.perm, self.data) for _ in range(self.batch)]
        src = pad_batch([p.src for p in pairs], self.model.max_src_len)
        # decoder input: BOS + ref[:-1]; target: ref
        tgt_in = pad_batch([p.ref[:-1] for p in pairs], self.model.max_tgt_len, bos=True)
        tgt_out = pad_batch([p.ref for p in pairs], self.model.max_tgt_len)
        return src, tgt_in, tgt_out


def export_splits(data: DataConfig, model: ModelConfig):
    """valid/test splits + lexicon, as plain dicts for JSON export."""
    lex = build_lexicon(data, model)
    perm = translation_permutation(data, model)
    valid = make_split(data.seed ^ 0x1111, data.n_valid, lex, perm, data)
    test = make_split(data.seed ^ 0x2222, data.n_test, lex, perm, data)
    calib_rng = SplitMix64(data.seed ^ 0x3333)
    calib_idx = sorted(set(calib_rng.below(data.n_valid) for _ in range(data.n_calibration * 3)))
    calib_idx = calib_idx[: data.n_calibration]

    def dump(pairs):
        return [
            {"src": p.src, "ref": p.ref, "n_words": p.n_words, "text": p.text}
            for p in pairs
        ]

    return {
        "lexicon": {"words": lex.words, "spellings": lex.spellings},
        "permutation": perm.tolist(),
        "valid": dump(valid),
        "test": dump(test),
        "calibration_indices": calib_idx,
    }
