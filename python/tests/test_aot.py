"""AOT pipeline tests: export formats + HLO text invariants.

The full pipeline (train + calibrate + lower) runs in `make artifacts`;
these tests exercise the pieces cheaply and, when artifacts exist,
validate the exported files' invariants that the Rust side relies on.
"""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import export, model as M
from compile.aot import to_hlo_text
from compile.common import ModelConfig

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_weights_roundtrip(tmp_path):
    params = {
        "b": np.arange(6, dtype=np.float32).reshape(2, 3),
        "a": np.asarray([1.5], dtype=np.float32),
    }
    export.write_weights(params, str(tmp_path))
    back = export.load_weights(str(tmp_path))
    assert set(back) == {"a", "b"}
    np.testing.assert_array_equal(back["b"], params["b"])
    np.testing.assert_array_equal(back["a"], params["a"])
    manifest = json.load(open(tmp_path / "manifest.json"))
    # sorted order contract (rust reads offsets in manifest order)
    assert [t["name"] for t in manifest["tensors"]] == ["a", "b"]
    assert manifest["total"] == 7


def test_hlo_text_contains_full_constants():
    """print_large_constants must be in effect — elided constants would
    silently zero the weights on the Rust side."""
    w = jnp.asarray(np.random.default_rng(0).standard_normal((40, 8)), jnp.float32)

    def fn(i):
        return (jnp.sum(w[i]),)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2,), jnp.int32))
    txt = to_hlo_text(lowered)
    assert "constant({...})" not in txt
    assert txt.count("constant({") >= 1


def test_hlo_text_is_tupled():
    def fn(x):
        return (x + 1.0, x * 2.0)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2,), jnp.float32))
    txt = to_hlo_text(lowered)
    assert "tuple(" in txt or "(f32[2]" in txt


def test_tiny_translate_lowering_has_while_loop():
    cfg = ModelConfig(
        vocab_size=16, d_model=16, n_heads=2, d_ff=32,
        n_enc_layers=1, n_dec_layers=1, max_src_len=8, max_tgt_len=8,
    )
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    def fn(src):
        return M.translate_greedy(params, cfg, src, max_len=8)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((1, 8), jnp.int32))
    txt = to_hlo_text(lowered)
    assert "while" in txt
    assert "constant({...})" not in txt


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "hlo_index.json")),
    reason="artifacts not built",
)
class TestBuiltArtifacts:
    def test_index_files_exist(self):
        idx = json.load(open(os.path.join(ARTIFACTS, "hlo_index.json")))
        assert len(idx["buckets"]) == 6  # {1,16,64} x {fp32,int8}
        for b in idx["buckets"]:
            path = os.path.join(ARTIFACTS, b["file"])
            assert os.path.exists(path), b["file"]
            head = open(path).read(200000)
            assert "HloModule" in head

    def test_no_elided_constants_in_artifacts(self):
        idx = json.load(open(os.path.join(ARTIFACTS, "hlo_index.json")))
        for b in idx["buckets"]:
            txt = open(os.path.join(ARTIFACTS, b["file"])).read()
            assert "constant({...})" not in txt, b["file"]

    def test_calibration_export_schema(self):
        cal = json.load(open(os.path.join(ARTIFACTS, "calibration.json")))
        assert "sites" in cal and "weight_scales" in cal
        for name, s in cal["sites"].items():
            assert s["class"] in ("sparse", "narrow", "gaussian")
            assert s["independent"][0] <= 0 <= s["independent"][1]
            assert s["symmetric"] > 0
        # every weight site has a scale
        cfg = ModelConfig()
        for site in M.matmul_site_names(cfg):
            if M.weight_for_site(cfg, site) is not None:
                assert site in cal["weight_scales"], site

    def test_config_matches_defaults(self):
        cfgd = json.load(open(os.path.join(ARTIFACTS, "config.json")))
        assert cfgd["model"]["d_model"] == ModelConfig().d_model
        assert cfgd["pad_id"] == 0 and cfgd["eos_id"] == 2
