"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the core kernel-correctness signal: hypothesis sweeps shapes,
scales and zero points; every Pallas output must match the reference
semantics exactly (integer domain) / to float tolerance (epilogue).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import qmatmul as pk
from compile.kernels import ref as kref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


dims = st.integers(min_value=1, max_value=33)
scales = st.floats(min_value=1e-3, max_value=0.5, allow_nan=False)
zeros = st.integers(min_value=-20, max_value=20)


def rand(shape, scale=1.0, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


class TestQuantizeKernels:
    @given(n=st.integers(1, 700), scale=scales, zero=zeros)
    def test_quantize_s8_matches_ref(self, n, scale, zero):
        x = rand((n,), 1.0, seed=n)
        got = pk.quantize_s8_pallas(jnp.asarray(x), scale, zero, block=64)
        want = kref.quantize_s8(jnp.asarray(x), scale, zero)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(n=st.integers(1, 700), scale=scales)
    def test_dequantize_s8_matches_ref(self, n, scale):
        q = (np.random.default_rng(n).integers(-128, 128, n)).astype(np.int8)
        got = pk.dequantize_s8_pallas(jnp.asarray(q), scale, 0, block=64)
        want = kref.dequantize_s8(jnp.asarray(q), scale, 0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    def test_quantize_saturates(self):
        x = jnp.asarray([1e6, -1e6, 0.0], jnp.float32)
        q = np.asarray(pk.quantize_s8_pallas(x, 0.1))
        assert q.tolist() == [127, -128, 0]

    def test_quantize_preserves_shape(self):
        x = jnp.zeros((3, 5, 7), jnp.float32)
        q = pk.quantize_s8_pallas(x, 0.1)
        assert q.shape == (3, 5, 7)
        assert q.dtype == jnp.int8


class TestQMatmul:
    @given(m=dims, k=dims, n=dims, za=zeros)
    def test_qmatmul_integer_exact_vs_ref(self, m, k, n, za):
        rng = np.random.default_rng(m * 1000 + k * 10 + n)
        a_q = rng.integers(-128, 128, (m, k)).astype(np.int8)
        b_q = rng.integers(0, 256, (k, n)).astype(np.uint8)
        got = pk.qmatmul_pallas(jnp.asarray(a_q), jnp.asarray(b_q), 0.02, 0.03,
                                za, bm=8, bn=8, bk=8)
        want = kref.qmatmul_ref(jnp.asarray(a_q), jnp.asarray(b_q), 0.02, 0.03, za)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    @given(m=dims, k=dims, n=dims, sa=scales, sb=scales)
    def test_fake_quant_matmul_matches_ref(self, m, k, n, sa, sb):
        a = rand((m, k), 1.0, seed=m + k)
        b = rand((k, n), 1.0, seed=k + n)
        got = pk.fake_quant_matmul(jnp.asarray(a), jnp.asarray(b), sa, sb,
                                   bm=8, bn=8, bk=8)
        want = kref.fake_quant_matmul_ref(jnp.asarray(a), jnp.asarray(b), sa, sb)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @given(blocks=st.sampled_from([(8, 8, 8), (16, 32, 8), (32, 64, 64), (128, 128, 128)]))
    def test_block_shape_invariance(self, blocks):
        """Different BlockSpec tilings must not change the numbers."""
        bm, bn, bk = blocks
        a_q = np.arange(-40, 40, dtype=np.int8).reshape(16, 5)
        b_q = (np.arange(16 * 5).reshape(5, 16) % 256).astype(np.uint8)
        base = kref.qmatmul_ref(jnp.asarray(a_q), jnp.asarray(b_q), 0.1, 0.1, 0)
        got = pk.qmatmul_pallas(jnp.asarray(a_q), jnp.asarray(b_q), 0.1, 0.1, 0,
                                bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-6)

    def test_k_padding_uses_neutral_values(self):
        """K not a multiple of bk: padded region must contribute zero."""
        a_q = np.full((4, 7), 5, np.int8)
        b_q = np.full((7, 4), 200, np.uint8)
        got = pk.qmatmul_pallas(jnp.asarray(a_q), jnp.asarray(b_q), 1.0, 1.0, 0,
                                bm=4, bn=4, bk=4)
        want = kref.qmatmul_ref(jnp.asarray(a_q), jnp.asarray(b_q), 1.0, 1.0, 0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))

    def test_quantized_tracks_float_product(self):
        """End-to-end fake-quant must approximate the float matmul."""
        a = rand((24, 48), 0.5, seed=1)
        b = rand((48, 16), 0.5, seed=2)
        exact = a @ b
        sa = float(np.abs(a).max()) / 127.0
        sb = float(np.abs(b).max()) / 127.0
        got = np.asarray(pk.fake_quant_matmul(jnp.asarray(a), jnp.asarray(b), sa, sb))
        err = np.abs(got - exact).mean()
        assert err < 0.05, f"mean abs err {err}"


class TestMatmulPallas:
    @given(m=dims, k=dims, n=dims)
    def test_matmul_matches_jnp(self, m, k, n):
        a = rand((m, k), 1.0, seed=m)
        b = rand((k, n), 1.0, seed=n)
        got = pk.matmul_pallas(jnp.asarray(a), jnp.asarray(b), bm=8, bn=8, bk=8)
        np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-4, atol=1e-4)
