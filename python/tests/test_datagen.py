"""Synthetic corpus tests: determinism, structure, rust parity contract."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import datagen
from compile.common import DataConfig, ModelConfig, EOS_ID, FIRST_CONTENT_ID

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")

CFG = DataConfig()
MODEL = ModelConfig()


def test_splitmix_golden_values():
    """Must match rust/src/util/rng.rs golden values exactly."""
    assert datagen.SplitMix64(0).next_u64() == 0x91A20293E6B0FF96
    assert datagen.SplitMix64(1).next_u64() == 0x77DEAE211FEB5FD2


def test_lexicon_deterministic_and_unique():
    a = datagen.build_lexicon(CFG, MODEL)
    b = datagen.build_lexicon(CFG, MODEL)
    assert a.words == b.words
    assert a.spellings == b.spellings
    assert len(set(map(tuple, a.spellings))) == CFG.n_words


def test_permutation_is_bijection():
    perm = datagen.translation_permutation(CFG, MODEL)
    n = MODEL.vocab_size - FIRST_CONTENT_ID
    assert sorted(perm.tolist()) == list(range(n))


def test_pairs_structure():
    lex = datagen.build_lexicon(CFG, MODEL)
    perm = datagen.translation_permutation(CFG, MODEL)
    pairs = datagen.make_split(99, 50, lex, perm, CFG)
    for p in pairs:
        assert p.src[-1] == EOS_ID
        assert p.ref[-1] == EOS_ID
        assert len(p.src) == len(p.ref)
        assert CFG.min_words <= p.n_words <= CFG.max_words
        # translation rule: ref = reversed permuted src
        body = p.src[:-1]
        expect = datagen.translate_tokens(body, perm)
        assert p.ref[:-1] == expect


@given(seed=st.integers(0, 2**32))
def test_splits_are_seed_deterministic(seed):
    lex = datagen.build_lexicon(CFG, MODEL)
    perm = datagen.translation_permutation(CFG, MODEL)
    a = datagen.make_split(seed, 3, lex, perm, CFG)
    b = datagen.make_split(seed, 3, lex, perm, CFG)
    assert [p.src for p in a] == [p.src for p in b]


def test_pad_batch_shapes():
    out = datagen.pad_batch([[3, 4, 2], [5, 2]], 6)
    assert out.shape == (2, 6)
    assert out.dtype == np.int32
    assert out[0].tolist() == [3, 4, 2, 0, 0, 0]
    assert out[1].tolist() == [5, 2, 0, 0, 0, 0]
    bos = datagen.pad_batch([[3, 4]], 4, bos=True)
    assert bos[0].tolist() == [1, 3, 4, 0]


def test_pad_batch_truncates():
    out = datagen.pad_batch([[3] * 10], 4)
    assert out.shape == (1, 4)


def test_export_splits_counts():
    small = DataConfig(n_valid=20, n_test=10, n_calibration=5)
    splits = datagen.export_splits(small, MODEL)
    assert len(splits["valid"]) == 20
    assert len(splits["test"]) == 10
    assert len(splits["calibration_indices"]) == 5
    assert all(0 <= i < 20 for i in splits["calibration_indices"])


def test_train_stream_batches():
    stream = datagen.TrainStream(CFG, MODEL, batch=4, seed=1)
    src, tgt_in, tgt_out = stream.next_batch()
    assert src.shape == (4, MODEL.max_src_len)
    assert tgt_in.shape == (4, MODEL.max_tgt_len)
    assert (tgt_in[:, 0] == 1).all()  # BOS
    # tgt_out is tgt_in shifted left by one
    assert (tgt_in[:, 1:10] == tgt_out[:, :9]).all()
