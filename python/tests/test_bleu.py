"""BLEU scorer tests (mirrored by rust/src/data/bleu.rs)."""

from hypothesis import given, settings, strategies as st

from compile.bleu import corpus_bleu, strip_special

settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")


def test_perfect_is_100():
    seqs = [[3, 4, 5, 6, 7]]
    assert abs(corpus_bleu(seqs, seqs) - 100.0) < 1e-9


def test_disjoint_is_0():
    assert corpus_bleu([[3, 4, 5, 6]], [[7, 8, 9, 10]]) == 0.0


def test_brevity_penalty():
    ref = [[3, 4, 5, 6, 7, 8, 9, 10]]
    short = [[3, 4, 5, 6, 7]]
    assert corpus_bleu(short, ref) < corpus_bleu(ref, ref)


def test_rust_parity_case():
    """Same case asserted in rust data::bleu tests."""
    h = [[10, 11, 12, 13, 14, 15, 16, 17]]
    r = [[10, 11, 12, 13, 14, 15, 16, 99]]
    b = corpus_bleu(h, r)
    assert 50.0 < b < 100.0


@given(
    seqs=st.lists(
        st.lists(st.integers(3, 95), min_size=4, max_size=20),
        min_size=1,
        max_size=5,
    )
)
def test_identity_is_100_for_4gram_capable(seqs):
    # corpora with any sequence shorter than 4 tokens legitimately score
    # 0 (no 4-grams), so restrict to >=4-token sequences here
    assert abs(corpus_bleu(seqs, seqs) - 100.0) < 1e-9


def test_short_corpus_scores_zero():
    # standard BLEU-4 behaviour: no 4-grams -> 0
    assert corpus_bleu([[3]], [[3]]) == 0.0


def test_strip_special():
    assert strip_special([3, 4, 2, 5], eos_id=2, pad_id=0) == [3, 4]
    assert strip_special([0, 3, 0], eos_id=2, pad_id=0) == [3]
    assert strip_special([2], eos_id=2, pad_id=0) == []
