"""L2 model tests: shapes, masking, decode semantics, quantized paths."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M
from compile.common import ModelConfig, EOS_ID, PAD_ID, BOS_ID


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(
        vocab_size=16, d_model=16, n_heads=2, d_ff=32,
        n_enc_layers=1, n_dec_layers=1, max_src_len=8, max_tgt_len=8,
    )
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_param_census(tiny):
    cfg, params = tiny
    # embed + enc(4 attn + 2*2 ln + 4 ffn) + dec(8 attn + 3*2 ln + 4 ffn)
    assert len(params) == 1 + 12 + 18


def test_encode_shape_and_determinism(tiny):
    cfg, params = tiny
    src = jnp.asarray([[3, 4, 5, 2, 0, 0, 0, 0]], jnp.int32)
    m1 = M.encode(params, cfg, src)
    m2 = M.encode(params, cfg, src)
    assert m1.shape == (1, 8, cfg.d_model)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


def test_pad_mask_blocks_attention(tiny):
    """Changing tokens under PAD positions must not change the encoding
    of non-pad positions."""
    cfg, params = tiny
    a = jnp.asarray([[3, 4, 2, 0, 0, 0, 0, 0]], jnp.int32)
    b = jnp.asarray([[3, 4, 2, 0, 0, 0, 0, 0]], jnp.int32)
    ma = np.asarray(M.encode(params, cfg, a))[:, :3]
    mb = np.asarray(M.encode(params, cfg, b))[:, :3]
    np.testing.assert_allclose(ma, mb, rtol=1e-6)


def test_causal_mask_in_teacher_decoder(tiny):
    """Changing a future target token must not change earlier logits."""
    cfg, params = tiny
    src = jnp.asarray([[3, 4, 5, 2, 0, 0, 0, 0]], jnp.int32)
    t1 = jnp.asarray([[BOS_ID, 6, 7, 8, 0, 0, 0, 0]], jnp.int32)
    t2 = jnp.asarray([[BOS_ID, 6, 7, 9, 0, 0, 0, 0]], jnp.int32)
    l1 = np.asarray(M.forward_teacher(params, cfg, src, t1))
    l2 = np.asarray(M.forward_teacher(params, cfg, src, t2))
    np.testing.assert_allclose(l1[:, :3], l2[:, :3], rtol=1e-5)
    assert not np.allclose(l1[:, 3], l2[:, 3])


def test_greedy_decode_shapes_and_pads(tiny):
    cfg, params = tiny
    src = jnp.asarray([[3, 4, 2, 0, 0, 0, 0, 0],
                       [5, 6, 7, 8, 2, 0, 0, 0]], jnp.int32)
    out, lens = jax.jit(lambda s: M.translate_greedy(params, cfg, s))(src)
    assert out.shape == (2, cfg.max_tgt_len)
    out = np.asarray(out)
    lens = np.asarray(lens)
    for b in range(2):
        row = out[b].tolist()
        if EOS_ID in row:
            eos = row.index(EOS_ID)
            assert all(t == PAD_ID for t in row[eos + 1:])


def test_greedy_matches_stepwise_teacher(tiny):
    """The while-loop decode must equal feeding its own output through
    the teacher-forced decoder (same argmax chain)."""
    cfg, params = tiny
    src = jnp.asarray([[3, 4, 5, 6, 2, 0, 0, 0]], jnp.int32)
    out, _ = M.translate_greedy(params, cfg, src)
    out = np.asarray(out)[0]
    # reconstruct: tgt_in = BOS + generated tokens
    gen = [t for t in out.tolist() if t != PAD_ID]
    tgt_in = np.full((1, cfg.max_tgt_len), PAD_ID, np.int32)
    tgt_in[0, 0] = BOS_ID
    tgt_in[0, 1:1 + len(gen) - 1] = gen[:-1] if len(gen) > 1 else []
    logits = np.asarray(M.forward_teacher(params, cfg, src, jnp.asarray(tgt_in)))
    for i, tok in enumerate(gen):
        assert int(np.argmax(logits[0, i])) == tok, f"step {i}"


def test_quantized_context_runs_and_stays_close(tiny):
    cfg, params = tiny
    table = {}
    for site in M.matmul_site_names(cfg):
        table[site] = (8.0 / 127.0, 0, 1.0 / 127.0)
    qctx = M.make_qctx(table)
    src = jnp.asarray([[3, 4, 5, 2, 0, 0, 0, 0]], jnp.int32)
    m_f = np.asarray(M.encode(params, cfg, src))
    m_q = np.asarray(M.encode(params, cfg, src, qctx=qctx))
    assert np.abs(m_f - m_q).mean() < 0.4


def test_site_names_cover_weights(tiny):
    cfg, params = tiny
    for site in M.matmul_site_names(cfg):
        w = M.weight_for_site(cfg, site)
        if w is None:
            assert site.endswith(".qk") or site.endswith(".pv")
        elif w != "embed.T":
            assert w in params, w


def test_loss_decreases_on_overfit_batch(tiny):
    """Three gradient steps on one batch must reduce the loss."""
    cfg, params = tiny
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(3, 16, (4, 8)), jnp.int32)
    tgt_in = jnp.asarray(rng.integers(3, 16, (4, 8)), jnp.int32)
    tgt_out = jnp.asarray(rng.integers(3, 16, (4, 8)), jnp.int32)
    loss0 = float(M.loss_fn(params, cfg, src, tgt_in, tgt_out))
    p = params
    for _ in range(3):
        g = jax.grad(M.loss_fn)(p, cfg, src, tgt_in, tgt_out)
        p = {k: p[k] - 0.1 * g[k] for k in p}
    loss1 = float(M.loss_fn(p, cfg, src, tgt_in, tgt_out))
    assert loss1 < loss0
