"""Calibration machinery tests: KL search, classification, modes."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import calibrate as C
from compile.common import HIST_BINS

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def stats_of(data):
    st_ = C.SiteStats()
    st_.observe_range(data)
    st_.observe_hist(data)
    return st_


class TestKL:
    def test_kl_zero_for_identical(self):
        p = np.asarray([1.0, 2.0, 3.0])
        assert C.kl_divergence(p, p) < 1e-12

    def test_kl_positive_for_different(self):
        p = np.asarray([3.0, 2.0, 1.0])
        q = np.asarray([1.0, 2.0, 3.0])
        assert C.kl_divergence(p, q) > 0

    def test_kl_inf_for_empty(self):
        assert math.isinf(C.kl_divergence(np.zeros(4), np.ones(4)))

    def test_quantize_hist_preserves_mass(self):
        ref = np.asarray([float(i % 7) for i in range(512)])
        q = C.quantize_hist(ref)
        assert abs(ref.sum() - q.sum()) < 1e-9 * ref.sum()

    def test_quantize_hist_keeps_zero_bins_zero(self):
        ref = np.zeros(256)
        ref[3] = 5.0
        q = C.quantize_hist(ref)
        assert q[3] > 0
        assert (q[np.arange(256) != 3] == 0).all()

    def test_longtail_clips_below_max(self):
        rng = np.random.default_rng(0)
        data = rng.standard_normal(300_000).astype(np.float32)
        data[rng.random(300_000) < 0.001] *= 50
        st_ = stats_of(data)
        t = C.kl_threshold(st_.hist_abs, st_.absmax / HIST_BINS)
        assert t < st_.absmax * 0.5
        assert t > 1.0

    def test_uniform_keeps_range(self):
        rng = np.random.default_rng(1)
        data = (rng.random(100_000).astype(np.float32) * 6 - 3)
        st_ = stats_of(data)
        t = C.kl_threshold(st_.hist_abs, st_.absmax / HIST_BINS)
        assert t > 2.4


class TestClassify:
    def test_relu_like_is_sparse(self):
        rng = np.random.default_rng(2)
        data = np.maximum(rng.standard_normal(50_000), 0).astype(np.float32)
        data[:15_000] = 0.0
        assert stats_of(data).classify() == "sparse"

    def test_probs_are_narrow(self):
        rng = np.random.default_rng(3)
        data = rng.random(50_000).astype(np.float32) * 0.9 + 0.05
        assert stats_of(data).classify() == "narrow"

    def test_activations_are_gaussian(self):
        rng = np.random.default_rng(4)
        data = (rng.standard_normal(50_000) * 2).astype(np.float32)
        assert stats_of(data).classify() == "gaussian"


class TestModes:
    @pytest.fixture(scope="class")
    def cal(self):
        rng = np.random.default_rng(5)
        data = rng.standard_normal(200_000).astype(np.float32)
        data[rng.random(200_000) < 0.0005] *= 40
        return C.calibrate_site("t", stats_of(data))

    def test_threshold_ordering(self, cal):
        assert 0 < cal.thr_symmetric <= cal.absmax()

    def test_mode_scales(self, cal):
        s_naive, z_naive = C.scale_for_mode(cal, "naive")
        s_sym, z_sym = C.scale_for_mode(cal, "symmetric")
        s_ind, z_ind = C.scale_for_mode(cal, "independent")
        s_con, z_con = C.scale_for_mode(cal, "conjugate")
        assert z_naive == 0 and z_sym == 0 and z_con == 0
        # naive covers outliers -> coarser (bigger) scale
        assert s_naive > s_sym
        # conjugate >= each independent half in magnitude terms
        assert cal.thr_conjugate >= cal.thr_independent[1] - 1e-9
        assert cal.thr_conjugate >= -cal.thr_independent[0] - 1e-9
        assert -128 <= z_ind <= 127

    def test_unknown_mode_raises(self, cal):
        with pytest.raises(ValueError):
            C.scale_for_mode(cal, "bogus")


def _absmax(self):
    return max(abs(self.amin), abs(self.amax))


# convenience used above
C.SiteCalibration.absmax = _absmax
